//! Integration: the Rust training driver over the AOT train/eval graphs.
//!
//! The train/eval graphs only exist on the xla backend (they embed the
//! backward pass + AdamW), so this whole suite is feature-gated; it
//! additionally skips at runtime when `artifacts/parity` is missing.
#![cfg(feature = "xla")]

use ladder_infer::runtime::{ArtifactDir, BackendKind, Exec};
use ladder_infer::trainer::{Corpus, Trainer};

/// The parity exec, or None (skip) when artifacts are absent.
fn exec() -> Option<Exec> {
    if ArtifactDir::open_named("parity").is_err() {
        eprintln!("skipping trainer integration: no artifacts/parity (run `make artifacts`)");
        return None;
    }
    Some(Exec::open("parity", BackendKind::Xla).expect("open parity artifacts on xla backend"))
}

#[test]
fn initial_loss_is_near_uniform() {
    let Some(e) = exec() else { return };
    let trainer = Trainer::new(&e).unwrap();
    let vocab = e.cfg().vocab as f64;
    let mut corpus = Corpus::new(vocab as usize, 4, 123);
    let m = trainer.eval("standard", &mut corpus, 2).unwrap();
    assert!((m.loss - vocab.ln()).abs() < 1.0, "loss {} vs ln(V) {}", m.loss, vocab.ln());
    assert!(m.accuracy < 0.1);
}

#[test]
fn train_step_reduces_loss_for_each_arch() {
    let Some(e) = exec() else { return };
    for arch in ["standard", "ladder", "desync2"] {
        let mut trainer = Trainer::new(&e).unwrap();
        let mut corpus = Corpus::new(e.cfg().vocab, 4, 7);
        let batch = corpus.batch(trainer.train_batch, trainer.train_seq);
        let first = trainer.train_step(arch, 2e-3, &batch).unwrap();
        let mut last = first;
        for _ in 0..6 {
            let tokens = corpus.batch(trainer.train_batch, trainer.train_seq);
            last = trainer.train_step(arch, 2e-3, &tokens).unwrap();
        }
        assert!(last < first, "{arch}: {first} -> {last}");
    }
}

#[test]
fn eval_is_deterministic_for_fixed_weights() {
    let Some(e) = exec() else { return };
    let trainer = Trainer::new(&e).unwrap();
    let m1 = trainer.eval("ladder", &mut Corpus::new(e.cfg().vocab, 4, 99), 2).unwrap();
    let m2 = trainer.eval("ladder", &mut Corpus::new(e.cfg().vocab, 4, 99), 2).unwrap();
    assert_eq!(m1.loss, m2.loss);
    assert_eq!(m1.accuracy, m2.accuracy);
}

#[test]
fn hybrid_zeroshot_differs_from_standard_eval() {
    // Same weights evaluated under standard vs hybrid computation flows
    // must differ (that is the representation shift the paper retrains
    // away).
    let Some(e) = exec() else { return };
    let mut trainer = Trainer::new(&e).unwrap();
    // a few training steps so the weights are not at the symmetric init
    let mut corpus = Corpus::new(e.cfg().vocab, 4, 3);
    for _ in 0..3 {
        let tokens = corpus.batch(trainer.train_batch, trainer.train_seq);
        trainer.train_step("standard", 2e-3, &tokens).unwrap();
    }
    let std_eval = trainer.eval("standard", &mut Corpus::new(e.cfg().vocab, 4, 55), 2).unwrap();
    let hyb_eval = trainer.eval("hybrid", &mut Corpus::new(e.cfg().vocab, 4, 55), 2).unwrap();
    assert!((std_eval.loss - hyb_eval.loss).abs() > 1e-4);
}

#[test]
fn reset_restores_the_seeded_init() {
    let Some(e) = exec() else { return };
    let mut trainer = Trainer::new(&e).unwrap();
    let w0 = trainer.w.clone();
    let mut corpus = Corpus::new(e.cfg().vocab, 4, 1);
    let tokens = corpus.batch(trainer.train_batch, trainer.train_seq);
    trainer.train_step("standard", 1e-3, &tokens).unwrap();
    assert_ne!(trainer.w, w0);
    trainer.reset().unwrap();
    assert_eq!(trainer.w, w0);
    assert_eq!(trainer.step, 0);
}
