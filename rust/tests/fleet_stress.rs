//! Fault-injection fleet harness for the multi-replica router tier.
//!
//! Drives a 3-replica [`Router`] (each replica its own paged batcher with
//! the prefix cache on, built from the same factory the `router` CLI
//! subcommand uses) through seeded workloads of shared-template prompts
//! while injecting the three fleet faults mid-workload:
//!
//! * **kill** — the busiest replica is crashed with requests in flight;
//!   its sinks drop without a terminal event and the router must retry
//!   (pre-first-token) or fail with a retryable `Error` (post-token),
//! * **drain** — a busy replica closes admission, bounces its queue
//!   (resubmitted elsewhere, invisibly to the client) and finishes its
//!   in-flight slots before retiring,
//! * **restart** — the drained replica is respawned cold.
//!
//! Across >= 3 seeds the harness asserts zero lost and zero duplicated
//! requests: every submitted request sees exactly one terminal event, at
//! most one `Admitted`, and gapless monotone token indices. Every stream
//! that finishes — including transparently retried ones — must be
//! **bitwise identical** to a solo run of the same request on a single
//! fresh batcher (same per-request RNG seed, so a replay reproduces the
//! original stream exactly).
//!
//! A separate acceptance test replays a fault-free shared-template
//! workload under both routing policies and asserts prefix-affinity
//! routing prefills **strictly fewer** aggregate tokens than round-robin
//! (affinity pays one cold prefix per template; round-robin pays one per
//! template per replica).
//!
//! The `fleet_ops_` scenarios exercise the heterogeneous-fleet tier on
//! top of the same invariants: a **rolling upgrade** across all three
//! replicas mid-workload (zero losses, bitwise-vs-oracle, every wave
//! lands the new config), a **ladder-vs-standard A/B split** under
//! identical seeded traffic (the ITL delta shows up on the replicas'
//! engines, not in router-side queue time), and a **dead-fleet backoff**
//! regression (linear backoff exhausts the retry ledger in bounded time;
//! the dispatch deadline caps even a huge ledger). CI runs them as their
//! own `--release` step (`-- fleet_ops_`).
//!
//! JSON reports go to `$FLEET_STRESS_REPORT` (CI) or
//! `target/tmp/FLEET_STRESS.json`; the affinity comparison writes the
//! sibling `FLEET_STRESS.affinity.json` so concurrent tests never race on
//! one file, and the A/B split writes `$FLEET_AB_REPORT` (or
//! `target/tmp/FLEET_AB.json`). CI uploads the `FLEET_STRESS*.json` and
//! `FLEET_AB*.json` globs next to the other stress reports.

use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::time::{Duration, Instant};

use ladder_infer::comm::{Fabric, Interconnect};
use ladder_infer::engine::{KvLayout, RuntimeKind, Sampler, TpEngine};
use ladder_infer::model::{Arch, WeightStore};
use ladder_infer::runtime::Exec;
use ladder_infer::server::{
    Batcher, BatcherConfig, GenerationEvent, ReplicaFactory, ReplicaSlotConfig, Request, Router,
    RouterConfig, RoutingPolicy,
};
use ladder_infer::util::json::Json;
use ladder_infer::util::rng::Rng;

/// KV page size shared by every replica; also the affinity key length, so
/// the routing key is exactly the first page — the unit the prefix cache
/// shares.
const PAGE: usize = 8;
const TEMPLATE_TOKENS: usize = 2 * PAGE;
const REPLICAS: usize = 3;

/// A parameterized respawn recipe: every incarnation built from the same
/// call is bitwise the same engine (tiny config, fixed weight seed),
/// differing only in cache state — what one `--replica` spec resolves to
/// in the `router` CLI subcommand. Arch / page size / prefill chunk /
/// fabric are the knobs the heterogeneous-fleet scenarios vary.
fn configured_factory(
    arch: Arch,
    page_size: usize,
    prefill_chunk: usize,
    fabric: Fabric,
) -> ReplicaFactory {
    Arc::new(move || {
        let exec = Rc::new(Exec::native_named("tiny").expect("native tiny config"));
        let weights = WeightStore::random(exec.cfg(), 0xbeef);
        let engine = TpEngine::with_layout(
            exec,
            &weights,
            2,
            arch,
            2,
            Interconnect::new(fabric),
            RuntimeKind::default(),
            KvLayout::Paged { page_size, pages: 64 },
        )
        .expect("tiny paged engine");
        let config = BatcherConfig {
            prefill_chunk,
            prefix_cache: true,
            ..BatcherConfig::default()
        };
        Ok(Batcher::new(engine, config))
    })
}

/// The homogeneous baseline recipe the fault-injection scenarios use.
fn replica_factory() -> ReplicaFactory {
    configured_factory(Arch::Ladder, PAGE, 4, Fabric::Local)
}

/// Seeded shared-template workload: `templates` random 2-page prompt
/// heads, `per_template` requests each with a unique random suffix. Every
/// third request samples (seeded top-k) instead of greedy decoding, so
/// retry-replay bitwise identity is exercised on sampled streams too.
fn workload(
    seed: u64,
    templates: usize,
    per_template: usize,
    suffix_tokens: usize,
    max_new: usize,
    id_base: u64,
) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    let heads: Vec<Vec<i32>> = (0..templates)
        .map(|_| (0..TEMPLATE_TOKENS).map(|_| rng.below(200) as i32).collect())
        .collect();
    let mut requests = Vec::new();
    // template-major order: one template's requests are consecutive, so a
    // round-robin router provably spreads each template across replicas
    // (the fair baseline for the affinity comparison)
    for head in &heads {
        for _ in 0..per_template {
            let id = id_base + requests.len() as u64;
            let mut prompt = head.clone();
            prompt.extend((0..suffix_tokens).map(|_| rng.below(200) as i32));
            let mut req = Request::new(id, prompt, max_new);
            if requests.len() % 3 == 2 {
                let sampler = Sampler::TopK { k: 8, temperature: 1.0, seed: 0x5eed + id };
                req = req.with_sampler(sampler);
            }
            requests.push(req);
        }
    }
    requests
}

/// Solo oracle: each request run to completion alone on one fresh-built
/// batcher (same factory as the replicas). Per-request seeding makes this
/// the bitwise ground truth for any fleet schedule, retried or not.
fn reference_outputs(requests: &[Request]) -> HashMap<u64, Vec<i32>> {
    let factory = replica_factory();
    let mut b = factory().expect("reference replica");
    let mut out = HashMap::new();
    for req in requests {
        b.submit(req.clone());
        let r = b.run_to_completion().expect("reference run").remove(0);
        out.insert(req.id, r.tokens);
    }
    out
}

/// Drain one client stream to its terminal event, asserting the stream
/// invariants on the way: at most one `Admitted`, gapless monotone token
/// indices, tokens matching the terminal result, exactly one terminal.
/// Returns `Ok(tokens)` for a finished stream, `Err((retryable, reason))`
/// for an errored one.
fn audit_stream(
    id: u64,
    rx: &Receiver<GenerationEvent>,
) -> Result<Vec<i32>, (bool, String)> {
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut admitted = 0usize;
    let mut streamed: Vec<i32> = Vec::new();
    loop {
        let remain = deadline.saturating_duration_since(Instant::now());
        let ev = rx
            .recv_timeout(remain)
            .unwrap_or_else(|_| panic!("request {id} lost: no terminal event arrived"));
        assert_eq!(ev.id(), id, "stream {id} received a foreign event");
        match ev {
            GenerationEvent::Admitted { .. } => {
                admitted += 1;
                assert_eq!(admitted, 1, "request {id}: duplicate Admitted frame");
                assert!(streamed.is_empty(), "request {id}: Admitted after tokens");
            }
            GenerationEvent::Token { index, token, .. } => {
                assert_eq!(
                    index,
                    streamed.len(),
                    "request {id}: token index gap or duplicate"
                );
                streamed.push(token);
            }
            GenerationEvent::Finished { result } => {
                assert_eq!(admitted, 1, "request {id}: finished without admission");
                assert_eq!(
                    result.tokens, streamed,
                    "request {id}: terminal result diverges from its own stream"
                );
                assert!(
                    rx.try_recv().is_err(),
                    "request {id}: events after the terminal"
                );
                return Ok(result.tokens);
            }
            GenerationEvent::Error { retryable, reason, .. } => {
                assert!(
                    rx.try_recv().is_err(),
                    "request {id}: events after the terminal"
                );
                return Err((retryable, reason));
            }
        }
    }
}

/// Per-replica `(up, outstanding)` pairs from a router stats snapshot.
fn replica_loads(stats: &Json) -> Vec<(bool, usize)> {
    stats
        .get("replicas")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|r| {
            (
                r.get("up").unwrap().as_bool().unwrap(),
                r.get("outstanding").unwrap().as_usize().unwrap(),
            )
        })
        .collect()
}

/// Poll until some live replica has work in flight and return its index
/// (best target for a fault that must land mid-request); falls back to
/// the first live replica if the fleet drains faster than we can look.
fn busiest_live_replica(router: &Router) -> usize {
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let loads = replica_loads(&router.stats().expect("stats"));
        let busiest = loads
            .iter()
            .enumerate()
            .filter(|(_, (up, _))| *up)
            .max_by_key(|(_, (_, n))| *n);
        match busiest {
            Some((idx, (_, n))) if *n > 0 || Instant::now() >= deadline => return idx,
            Some(_) => {}
            None => assert!(
                Instant::now() < deadline,
                "no live replica to target for fault injection"
            ),
        }
        std::thread::sleep(Duration::from_millis(1));
    }
}

fn stat(stats: &Json, key: &str) -> usize {
    stats.get(key).unwrap().as_usize().unwrap()
}

fn report_path(suffix: Option<&str>) -> PathBuf {
    let path = std::env::var("FLEET_STRESS_REPORT").map(PathBuf::from).unwrap_or_else(|_| {
        PathBuf::from(env!("CARGO_TARGET_TMPDIR")).join("FLEET_STRESS.json")
    });
    match suffix {
        Some(s) => path.with_extension(format!("{s}.json")),
        None => path,
    }
}

fn write_report(suffix: Option<&str>, report: Json) {
    let path = report_path(suffix);
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    std::fs::write(&path, report.to_string()).expect("write fleet report");
}

/// The tentpole acceptance test: kill, drain and restart replicas
/// mid-workload across three seeds; no request may be lost or duplicated,
/// and every finished stream must match the solo oracle bitwise.
#[test]
fn fleet_survives_kill_drain_restart_across_seeds() {
    let mut entries = Vec::new();
    let mut total_retries = 0usize;
    let mut total_lost = 0usize;
    for &seed in &[0xA1u64, 0xB2, 0xC3] {
        let requests = workload(seed, 6, 4, PAGE, 6, seed * 1000);
        let reference = reference_outputs(&requests);
        let cfg = RouterConfig {
            replicas: REPLICAS,
            policy: RoutingPolicy::Affinity,
            affinity_tokens: PAGE,
            spill_threshold: 64,
            max_retries: 8,
            retry_backoff: Duration::from_millis(2),
            dispatch_timeout: Duration::from_secs(60),
            auto_restart: true,
        };
        let router = Router::new(replica_factory(), cfg).expect("router");
        let mut rxs: Vec<(u64, Receiver<GenerationEvent>)> = Vec::new();
        let mut submit_wave = |router: &Router, wave: &[Request]| {
            for req in wave {
                let (tx, rx) = channel();
                rxs.push((req.id, rx));
                router.submit(req.clone(), tx);
            }
        };
        let waves: Vec<&[Request]> = requests.chunks(8).collect();
        assert_eq!(waves.len(), 3);
        // wave 1, then crash the replica with the most dispatches in
        // flight: pre-token requests must be retried transparently
        submit_wave(&router, waves[0]);
        let kill_target = busiest_live_replica(&router);
        router.kill(kill_target);
        // wave 2, then gracefully drain the (now) busiest replica: its
        // queue bounces and is resubmitted, in-flight slots finish
        submit_wave(&router, waves[1]);
        let drain_target = busiest_live_replica(&router);
        router.drain(drain_target);
        // wave 3 runs on the remaining live replicas
        submit_wave(&router, waves[2]);

        let mut finished = 0usize;
        let mut errored = 0usize;
        for (id, rx) in &rxs {
            match audit_stream(*id, rx) {
                Ok(tokens) => {
                    finished += 1;
                    assert_eq!(
                        &tokens, &reference[id],
                        "request {id}: fleet output (possibly retried) diverged from \
                         the solo oracle — retry replay is not bitwise-identical"
                    );
                }
                Err((retryable, reason)) => {
                    errored += 1;
                    assert!(
                        retryable,
                        "request {id}: fleet-condition failure must be retryable ({reason})"
                    );
                    assert!(!reason.is_empty());
                }
            }
        }
        assert_eq!(finished + errored, requests.len(), "zero lost, zero duplicated");
        // settle the router's own bookkeeping before reading stats
        let deadline = Instant::now() + Duration::from_secs(10);
        while router.completed() < requests.len() {
            assert!(Instant::now() < deadline, "router completed() never converged");
            std::thread::sleep(Duration::from_millis(1));
        }
        // the drained replica retires once its in-flight work is done;
        // restart it and watch it come back up
        let deadline = Instant::now() + Duration::from_secs(30);
        while replica_loads(&router.stats().unwrap())[drain_target].0 {
            assert!(Instant::now() < deadline, "drained replica never retired");
            std::thread::sleep(Duration::from_millis(2));
        }
        router.restart(drain_target);
        let deadline = Instant::now() + Duration::from_secs(30);
        while !replica_loads(&router.stats().unwrap())[drain_target].0 {
            assert!(Instant::now() < deadline, "restarted replica never came up");
            std::thread::sleep(Duration::from_millis(2));
        }
        let stats = router.stats().expect("final stats");
        assert_eq!(stat(&stats, "completed"), requests.len());
        assert_eq!(stat(&stats, "in_flight"), 0);
        assert_eq!(stat(&stats, "drains"), 1);
        assert_eq!(stat(&stats, "failed"), errored);
        assert!(
            stat(&stats, "restarts") >= 2,
            "kill auto-restart + explicit restart of the drained replica"
        );
        total_retries += stat(&stats, "retries");
        total_lost += stat(&stats, "lost_streams");
        entries.push(
            Json::obj()
                .set("seed", seed as usize)
                .set("requests", requests.len())
                .set("finished", finished)
                .set("errored", errored)
                .set("kill_target", kill_target)
                .set("drain_target", drain_target)
                .set("retries", stat(&stats, "retries"))
                .set("restarts", stat(&stats, "restarts"))
                .set("lost_streams", stat(&stats, "lost_streams"))
                .set("spilled", stat(&stats, "spilled"))
                .set(
                    "invariants",
                    "one-terminal-per-stream, no-dup-admit, monotone-tokens, \
                     bitwise-vs-solo-oracle, retryable-errors-only",
                ),
        );
        drop(router);
    }
    assert!(
        total_retries > 0 && total_lost > 0,
        "faults never landed mid-request across any seed \
         (retries {total_retries}, lost {total_lost}) — the harness is not \
         exercising the retry path"
    );
    let report =
        Json::obj().set("harness", "fleet_stress").set("seeds", Json::Arr(entries));
    write_report(None, report);
}

/// Acceptance: on the shared-template workload, prefix-affinity routing
/// must prefill strictly fewer aggregate tokens than round-robin —
/// affinity pays one cold template prefix per template, round-robin one
/// per template per replica it lands on.
#[test]
fn affinity_routing_prefills_fewer_tokens_than_round_robin() {
    let requests = workload(0x7a11, 6, 6, PAGE, 4, 50_000);
    let mut totals = Vec::new();
    for policy in [RoutingPolicy::Affinity, RoutingPolicy::RoundRobin] {
        let cfg = RouterConfig {
            replicas: REPLICAS,
            policy,
            affinity_tokens: PAGE,
            spill_threshold: 1_000, // sequential load never spills: isolate the policy
            max_retries: 2,
            retry_backoff: Duration::from_millis(2),
            dispatch_timeout: Duration::from_secs(60),
            auto_restart: true,
        };
        let router = Router::new(replica_factory(), cfg).expect("router");
        for req in &requests {
            let (tx, rx) = channel();
            router.submit(req.clone(), tx);
            // sequential: each request settles before the next routes, so
            // per-replica cache state is deterministic for both policies
            let tokens = audit_stream(req.id, &rx)
                .unwrap_or_else(|(_, e)| panic!("fault-free run errored: {e}"));
            assert_eq!(tokens.len(), req.max_new_tokens);
        }
        let stats = router.stats().expect("stats");
        assert_eq!(stat(&stats, "spilled"), 0);
        totals.push(stat(&stats, "prefill_tokens"));
        drop(router);
    }
    let (affinity, round_robin) = (totals[0], totals[1]);
    assert!(
        affinity < round_robin,
        "affinity routing must prefill strictly fewer tokens than round-robin \
         on shared templates (affinity {affinity}, round-robin {round_robin})"
    );
    write_report(
        Some("affinity"),
        Json::obj()
            .set("harness", "fleet_stress")
            .set("workload", "6 templates x 6 requests, 3 replicas, sequential")
            .set("affinity_prefill_tokens", affinity)
            .set("round_robin_prefill_tokens", round_robin),
    );
}

/// Regression (queue-time accounting): a request that sits waiting while
/// the only replica is down — being redispatched by the retry loop the
/// whole time — must report the *full* wall-clock wait in `queued_secs`,
/// on both its `Admitted` frame and its terminal result. The router
/// re-dispatches a clone of the original request, whose arrival stamp was
/// set exactly once at submission; a retry that rebuilt the request (or
/// otherwise restarted its clock) would make post-outage queue
/// percentiles look healthy while clients were in fact waiting out the
/// whole outage.
#[test]
fn router_retries_preserve_queue_time_across_replica_outage() {
    let cfg = RouterConfig {
        replicas: 1,
        policy: RoutingPolicy::RoundRobin,
        affinity_tokens: PAGE,
        spill_threshold: 1_000,
        max_retries: 10_000,
        retry_backoff: Duration::from_millis(5),
        dispatch_timeout: Duration::from_secs(60),
        auto_restart: false,
    };
    let router = Router::new(replica_factory(), cfg).expect("router");
    // take the only replica down and let the router notice
    router.kill(0);
    let deadline = Instant::now() + Duration::from_secs(10);
    while replica_loads(&router.stats().expect("stats"))[0].0 {
        assert!(Instant::now() < deadline, "killed replica never went down");
        std::thread::sleep(Duration::from_millis(1));
    }
    // submit into the outage: the request can only wait and be retried
    let (tx, rx) = channel();
    let t0 = Instant::now();
    router.submit(Request::new(4242, vec![3, 1, 4, 1, 5, 9, 2, 6], 4), tx);
    let outage = Duration::from_millis(250);
    std::thread::sleep(outage);
    router.restart(0);
    // raw event loop rather than `audit_stream`: queued_secs is the point
    let mut admitted_queued: Option<f64> = None;
    let mut result_queued: Option<f64> = None;
    let deadline = Instant::now() + Duration::from_secs(60);
    while result_queued.is_none() {
        let remain = deadline.saturating_duration_since(Instant::now());
        match rx.recv_timeout(remain).expect("request lost during the outage") {
            GenerationEvent::Admitted { id, queued_secs } => {
                assert_eq!(id, 4242);
                assert!(admitted_queued.is_none(), "duplicate Admitted after retries");
                admitted_queued = Some(queued_secs);
            }
            GenerationEvent::Token { .. } => {}
            GenerationEvent::Finished { result } => {
                assert_eq!(result.tokens.len(), 4);
                result_queued = Some(result.queued_secs);
            }
            GenerationEvent::Error { reason, .. } => {
                panic!("request failed instead of waiting out the outage: {reason}")
            }
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let admitted_queued = admitted_queued.expect("finished without an Admitted frame");
    let result_queued = result_queued.unwrap();
    let floor = outage.as_secs_f64();
    assert!(
        admitted_queued >= floor,
        "Admitted queued_secs {admitted_queued:.3}s forgot the outage wait \
         (>= {floor:.3}s expected): a retry reset the queue clock"
    );
    assert!(
        result_queued >= floor,
        "result queued_secs {result_queued:.3}s forgot the outage wait \
         (>= {floor:.3}s expected): a retry reset the queue clock"
    );
    assert!(
        admitted_queued <= elapsed && result_queued <= elapsed,
        "queued_secs ({admitted_queued:.3}s / {result_queued:.3}s) exceeds the \
         request's whole lifetime ({elapsed:.3}s)"
    );
    let stats = router.stats().expect("stats");
    assert!(stat(&stats, "retries") > 0, "the outage never exercised the retry path");
    assert_eq!(stat(&stats, "failed"), 0);
}

// --- heterogeneous-fleet operations scenarios (CI: their own release step) --

/// A slot recipe for the heterogeneous scenarios: the factory plus the
/// stats-visible description the router surfaces as `config`.
fn described_slot(
    arch: Arch,
    page_size: usize,
    prefill_chunk: usize,
    fabric: Fabric,
    rev: &str,
) -> ReplicaSlotConfig {
    ReplicaSlotConfig::with_desc(
        configured_factory(arch, page_size, prefill_chunk, fabric),
        Json::obj()
            .set("arch", if matches!(arch, Arch::Ladder) { "ladder" } else { "standard" })
            .set("page_size", page_size)
            .set("prefill_chunk", prefill_chunk)
            .set("rev", rev),
    )
}

/// Poll until every replica reports down (a dead factory retires its
/// replica shortly after its thread boots).
fn wait_fleet_down(router: &Router) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while replica_loads(&router.stats().expect("stats")).iter().any(|(up, _)| *up) {
        assert!(Instant::now() < deadline, "dead-factory replicas never retired");
        std::thread::sleep(Duration::from_millis(1));
    }
}

fn statf(obj: &Json, key: &str) -> f64 {
    obj.get(key).unwrap().as_f64().unwrap()
}

/// Acceptance (a): a rolling upgrade across all three replicas
/// mid-workload loses zero requests and duplicates none, with every
/// stream bitwise-equal to the solo oracle. The v2 config halves the KV
/// page size and prefill chunk — layout knobs, not semantics, so v1 and
/// v2 replicas are output-identical and the drain→respawn waves are
/// invisible to clients; afterwards every slot must both *advertise* and
/// actually *run* the v2 engine.
#[test]
fn fleet_ops_rolling_upgrade_loses_nothing() {
    let requests = workload(0x09A7, 6, 4, PAGE, 6, 90_000);
    let reference = reference_outputs(&requests);
    let cfg = RouterConfig {
        replicas: REPLICAS,
        policy: RoutingPolicy::Affinity,
        affinity_tokens: PAGE,
        spill_threshold: 64,
        max_retries: 8,
        retry_backoff: Duration::from_millis(2),
        dispatch_timeout: Duration::from_secs(60),
        auto_restart: true,
    };
    let v1 = (0..REPLICAS)
        .map(|_| described_slot(Arch::Ladder, PAGE, 4, Fabric::Local, "v1"))
        .collect();
    let router = Router::new_fleet(v1, cfg).expect("router");
    let mut rxs: Vec<(u64, Receiver<GenerationEvent>)> = Vec::new();
    let mut submit_wave = |router: &Router, wave: &[Request]| {
        for req in wave {
            let (tx, rx) = channel();
            rxs.push((req.id, rx));
            router.submit(req.clone(), tx);
        }
    };
    let waves: Vec<&[Request]> = requests.chunks(8).collect();
    assert_eq!(waves.len(), 3);
    // wave 1 in flight, then roll the whole fleet onto v2
    submit_wave(&router, waves[0]);
    let v2 = (0..REPLICAS)
        .map(|_| described_slot(Arch::Ladder, PAGE / 2, 2, Fabric::Local, "v2"))
        .collect();
    let ack = router.upgrade(v2).expect("upgrade control roundtrip");
    assert!(ack.opt("error").is_none(), "upgrade rejected: {ack:?}");
    assert_eq!(stat(&ack, "waves"), REPLICAS);
    // keep the workload flowing while the waves roll
    submit_wave(&router, waves[1]);
    submit_wave(&router, waves[2]);
    let mut finished = 0usize;
    for (id, rx) in &rxs {
        let tokens = audit_stream(*id, rx).unwrap_or_else(|(_, reason)| {
            panic!("request {id} errored during the rolling upgrade: {reason}")
        });
        assert_eq!(
            &tokens, &reference[id],
            "request {id}: output diverged from the solo oracle across the upgrade"
        );
        finished += 1;
    }
    assert_eq!(finished, requests.len(), "zero lost, zero duplicated");
    // the upgrade keeps rolling after traffic stops; wait for the last
    // wave to respawn its replica
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let stats = router.stats().expect("stats");
        let all_up = replica_loads(&stats).iter().all(|(up, _)| *up);
        if matches!(stats.get("upgrade"), Ok(Json::Null)) && all_up {
            break;
        }
        assert!(Instant::now() < deadline, "rolling upgrade never completed");
        std::thread::sleep(Duration::from_millis(2));
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    while router.completed() < requests.len() {
        assert!(Instant::now() < deadline, "router completed() never converged");
        std::thread::sleep(Duration::from_millis(1));
    }
    let stats = router.stats().expect("final stats");
    assert_eq!(stat(&stats, "completed"), requests.len());
    assert_eq!(stat(&stats, "failed"), 0, "a rolling upgrade must not fail requests");
    assert_eq!(stat(&stats, "lost_streams"), 0, "drain waves must not lose streams");
    assert_eq!(stat(&stats, "in_flight"), 0);
    assert_eq!(stat(&stats, "drains"), REPLICAS, "one drain wave per replica");
    assert_eq!(stat(&stats, "restarts"), REPLICAS, "one respawn per replica");
    for rep in stats.get("replicas").unwrap().as_arr().unwrap() {
        let config = rep.get("config").unwrap();
        assert_eq!(config.get("rev").unwrap().as_str().unwrap(), "v2");
        assert_eq!(config.get("page_size").unwrap().as_usize().unwrap(), PAGE / 2);
        let engine = rep.get("engine").unwrap();
        assert_eq!(
            engine.get("page_size").unwrap().as_usize().unwrap(),
            PAGE / 2,
            "replica advertises v2 but its engine still runs the old page size"
        );
    }
    write_report(
        Some("upgrade"),
        Json::obj()
            .set("harness", "fleet_stress")
            .set("scenario", "rolling upgrade, 3 waves mid-workload")
            .set("requests", requests.len())
            .set("finished", finished)
            .set("drains", stat(&stats, "drains"))
            .set("restarts", stat(&stats, "restarts"))
            .set("retries", stat(&stats, "retries"))
            .set(
                "invariants",
                "zero-failed, zero-lost, bitwise-vs-solo-oracle, config-and-engine-on-v2",
            ),
    );
}

/// Acceptance (b): a mixed ladder/standard fleet under identical seeded
/// traffic shows the inter-token-latency delta on the replicas' engines
/// — the ladder arch hides decode-phase collectives that the standard
/// arch exposes — while router-side queue time stays far too small to
/// explain the gap. The delta is the architecture, not the router.
#[test]
fn fleet_ops_ab_split_attributes_itl_to_the_arch() {
    // the "slow" fabric preset: 3ms latency, 1 GB/s — exposed collective
    // latency dominates decode, which is exactly the regime the paper's
    // ladder-residual rewiring targets
    let slow = Fabric::Custom(3000, 1);
    let slots = vec![
        described_slot(Arch::Ladder, PAGE, 4, slow, "ab"),
        described_slot(Arch::Standard, PAGE, 4, slow, "ab"),
    ];
    let cfg = RouterConfig {
        replicas: 2,
        policy: RoutingPolicy::RoundRobin,
        affinity_tokens: PAGE,
        spill_threshold: 1_000, // sequential load never spills
        max_retries: 2,
        retry_backoff: Duration::from_millis(2),
        dispatch_timeout: Duration::from_secs(60),
        auto_restart: true,
    };
    let router = Router::new_fleet(slots, cfg).expect("router");
    // identical seeded traffic: each prompt is submitted twice back to
    // back and settled before the next pair; round-robin over two live
    // replicas alternates deterministically, so both replicas decode the
    // same prompt sequence in the same order
    let mut rng = Rng::new(0xab5eed);
    let mut id = 70_000u64;
    for _ in 0..8 {
        let prompt: Vec<i32> = (0..TEMPLATE_TOKENS).map(|_| rng.below(200) as i32).collect();
        for _ in 0..2 {
            let req = Request::new(id, prompt.clone(), 6);
            id += 1;
            let (tx, rx) = channel();
            router.submit(req.clone(), tx);
            let tokens = audit_stream(req.id, &rx)
                .unwrap_or_else(|(_, reason)| panic!("fault-free A/B run errored: {reason}"));
            assert_eq!(tokens.len(), 6);
        }
    }
    let stats = router.stats().expect("stats");
    assert_eq!(stat(&stats, "failed"), 0);
    let reps = stats.get("replicas").unwrap().as_arr().unwrap();
    let ladder = reps[0].get("engine").unwrap();
    let standard = reps[1].get("engine").unwrap();
    assert_eq!(ladder.get("arch").unwrap().as_str().unwrap(), "ladder");
    assert_eq!(standard.get("arch").unwrap().as_str().unwrap(), "standard");
    // the split was fair: same requests, same tokens on each side
    assert_eq!(stat(ladder, "completed"), 8);
    assert_eq!(stat(standard, "completed"), 8);
    assert_eq!(stat(ladder, "tokens_out"), stat(standard, "tokens_out"));
    let itl_ladder = statf(ladder, "itl_p50_ms");
    let itl_standard = statf(standard, "itl_p50_ms");
    assert!(
        itl_ladder < itl_standard,
        "ladder replicas must decode faster than standard on a slow fabric \
         (ladder {itl_ladder:.3}ms, standard {itl_standard:.3}ms)"
    );
    let hidden_ladder = statf(ladder, "comm_hidden_fraction_decode");
    let hidden_standard = statf(standard, "comm_hidden_fraction_decode");
    assert!(
        hidden_ladder > hidden_standard,
        "the ITL win must come from hidden decode communication \
         (ladder {hidden_ladder:.3}, standard {hidden_standard:.3})"
    );
    // attribution: router-side queue time on both replicas is smaller
    // than the ITL delta itself, so queueing cannot explain the gap
    let delta = itl_standard - itl_ladder;
    let queue_ladder = statf(ladder, "queue_p50_ms");
    let queue_standard = statf(standard, "queue_p50_ms");
    assert!(
        queue_ladder < delta && queue_standard < delta,
        "router-side queue time (ladder {queue_ladder:.3}ms, standard \
         {queue_standard:.3}ms) is large enough to explain the ITL delta \
         ({delta:.3}ms) — the A/B attribution is broken"
    );
    let path = std::env::var("FLEET_AB_REPORT")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_TARGET_TMPDIR")).join("FLEET_AB.json"));
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    let report = Json::obj()
        .set("harness", "fleet_stress")
        .set("scenario", "ladder-vs-standard A/B, slow fabric, paired traffic")
        .set("itl_p50_ms_ladder", itl_ladder)
        .set("itl_p50_ms_standard", itl_standard)
        .set("comm_hidden_fraction_decode_ladder", hidden_ladder)
        .set("comm_hidden_fraction_decode_standard", hidden_standard)
        .set("queue_p50_ms_ladder", queue_ladder)
        .set("queue_p50_ms_standard", queue_standard);
    std::fs::write(&path, report.to_string()).expect("write A/B report");
}

/// Acceptance (c) / backoff regression: a fully-dead fleet exhausts the
/// retry ledger in bounded time — linear backoff (attempt k waits
/// k × base) with every failed placement counted — instead of polling at
/// a flat rate forever; and when the ledger is effectively unbounded, the
/// dispatch deadline cuts the request off instead.
#[test]
fn fleet_ops_dead_fleet_exhausts_retries_within_the_deadline() {
    let dead: ReplicaFactory = Arc::new(|| anyhow::bail!("injected build failure"));
    let dead_slots =
        |n: usize| (0..n).map(|_| ReplicaSlotConfig::new(dead.clone())).collect::<Vec<_>>();
    // phase 1: the ledger trips first — max_retries=5 at base 5ms waits
    // 5+10+15+20+25 = 75ms, nowhere near the 30s deadline
    let cfg = RouterConfig {
        replicas: 2,
        policy: RoutingPolicy::Affinity,
        affinity_tokens: PAGE,
        spill_threshold: 8,
        max_retries: 5,
        retry_backoff: Duration::from_millis(5),
        dispatch_timeout: Duration::from_secs(30),
        auto_restart: false,
    };
    let router = Router::new_fleet(dead_slots(2), cfg.clone()).expect("router");
    wait_fleet_down(&router);
    let (tx, rx) = channel();
    let t0 = Instant::now();
    router.submit(Request::new(1, vec![1, 2, 3], 4), tx);
    let (retryable, reason) = audit_stream(1, &rx).expect_err("a dead fleet cannot serve");
    let elapsed = t0.elapsed();
    assert!(retryable, "fleet-condition failures must be retryable");
    assert!(reason.contains("retries exhausted"), "wrong failure: {reason}");
    assert!(reason.contains("no live replica"), "last placement loss not surfaced: {reason}");
    assert!(
        elapsed >= Duration::from_millis(70),
        "linear backoff must actually wait between attempts (elapsed {elapsed:?})"
    );
    assert!(
        elapsed < Duration::from_secs(5),
        "a dead fleet must exhaust retries in bounded time (elapsed {elapsed:?})"
    );
    let stats = router.stats().expect("stats");
    assert_eq!(stat(&stats, "retries"), 5, "exactly max_retries redispatches are scheduled");
    assert_eq!(stat(&stats, "failed"), 1);
    assert_eq!(stat(&stats, "in_flight"), 0);
    drop(router);
    // phase 2: the deadline trips first — an effectively unbounded
    // ledger must still be cut off by dispatch_timeout
    let cfg = RouterConfig {
        max_retries: 100_000,
        retry_backoff: Duration::from_millis(1),
        dispatch_timeout: Duration::from_millis(250),
        ..cfg
    };
    let router = Router::new_fleet(dead_slots(2), cfg).expect("router");
    wait_fleet_down(&router);
    let (tx, rx) = channel();
    let t0 = Instant::now();
    router.submit(Request::new(2, vec![1, 2, 3], 4), tx);
    let (retryable, reason) = audit_stream(2, &rx).expect_err("a dead fleet cannot serve");
    let elapsed = t0.elapsed();
    assert!(retryable);
    assert!(reason.contains("dispatch timeout"), "wrong failure: {reason}");
    assert!(elapsed >= Duration::from_millis(250), "deadline fired early (elapsed {elapsed:?})");
    assert!(
        elapsed < Duration::from_secs(5),
        "the dispatch deadline must bound the wait (elapsed {elapsed:?})"
    );
}
