//! Integration: the Rust TP engine (native modules + rust scheduling + rust
//! collectives) must reproduce the python SimEngine's golden logits for
//! every architecture, for prefill and teacher-forced KV-cache decode.
//!
//! Golden vectors are produced at artifact-build time (aot.py
//! export_testvectors) — seeded weights, seeded tokens, per-step logits.
//! They are plain `.f32` files, so this parity check needs `make artifacts`
//! but **not** the xla toolchain; without an artifact directory the tests
//! skip with a note (the native backend itself is covered artifact-free by
//! `runtime_determinism` and the unit suites).

use ladder_infer::comm::{Fabric, Interconnect};
use ladder_infer::engine::TpEngine;
use ladder_infer::model::{Arch, WeightStore};
use ladder_infer::runtime::{ArtifactDir, Exec};

use std::rc::Rc;

struct TestVec {
    exec: Rc<Exec>,
    weights: WeightStore,
    tokens: Vec<i32>,
    tp: usize,
    batch: usize,
    prompt: usize,
    steps: usize,
    vocab: usize,
}

/// Load the golden test vectors, or None when artifacts are absent.
fn load() -> Option<TestVec> {
    if ArtifactDir::open_named("tiny").is_err() {
        eprintln!("skipping golden-logit parity: no artifacts/tiny (run `make artifacts`)");
        return None;
    }
    let exec = Rc::new(Exec::native_named("tiny").unwrap());
    let art = exec.artifacts().unwrap();
    let tv = art.manifest.get("testvec").unwrap();
    let tp = tv.get("tp").unwrap().as_usize().unwrap();
    let batch = tv.get("batch").unwrap().as_usize().unwrap();
    let prompt = tv.get("prompt").unwrap().as_usize().unwrap();
    let steps = tv.get("steps").unwrap().as_usize().unwrap();
    let flat = art.read_f32("testvec_weights.f32").unwrap();
    let weights =
        WeightStore::from_flat(&flat, art.packing().unwrap(), art.config.layers).unwrap();
    let tokens = art.read_i32("testvec_tokens.i32").unwrap();
    let vocab = art.config.vocab;
    Some(TestVec { exec, weights, tokens, tp, batch, prompt, steps, vocab })
}

fn expected(exec: &Exec, arch: &str) -> Vec<f32> {
    exec.artifacts()
        .unwrap()
        .read_f32(&format!("testvec_logits_{arch}.f32"))
        .unwrap()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

fn check_arch(arch: Arch) {
    let Some(tv) = load() else { return };
    let want = expected(&tv.exec, &arch.name());
    let step_len = tv.batch * tv.vocab;
    assert_eq!(want.len(), (tv.steps + 1) * step_len, "golden file size");

    let mut engine = TpEngine::new(
        tv.exec.clone(),
        &tv.weights,
        tv.tp,
        arch,
        tv.batch,
        Interconnect::new(Fabric::Local),
    )
    .unwrap();

    // prefill: tokens[:, :prompt] (row-major [B, prompt+steps])
    let total = tv.prompt + tv.steps;
    let mut prefill_tokens = vec![0i32; tv.batch * tv.prompt];
    for b in 0..tv.batch {
        prefill_tokens[b * tv.prompt..(b + 1) * tv.prompt]
            .copy_from_slice(&tv.tokens[b * total..b * total + tv.prompt]);
    }
    let true_lens = vec![tv.prompt; tv.batch];
    let logits = engine.prefill(&prefill_tokens, tv.prompt, &true_lens).unwrap();
    let diff = max_abs_diff(&logits.data, &want[..step_len]);
    // tiny artifacts use Pallas kernels, this oracle uses the native ref
    // math: small fp divergence from different reduction orders is expected.
    assert!(diff < 2e-3, "{}: prefill logits diff {diff}", arch.name());

    // teacher-forced decode
    for t in 0..tv.steps {
        let step_tokens: Vec<i32> = (0..tv.batch)
            .map(|b| tv.tokens[b * total + tv.prompt + t])
            .collect();
        let logits = engine.decode(&step_tokens).unwrap();
        let want_step = &want[(t + 1) * step_len..(t + 2) * step_len];
        let diff = max_abs_diff(&logits.data, want_step);
        assert!(diff < 2e-3, "{}: decode step {t} diff {diff}", arch.name());
    }
}

#[test]
fn standard_matches_golden() {
    check_arch(Arch::Standard);
}

#[test]
fn ladder_matches_golden() {
    check_arch(Arch::Ladder);
}

#[test]
fn parallel_matches_golden() {
    check_arch(Arch::Parallel);
}

#[test]
fn hybrid_matches_golden() {
    check_arch(Arch::Hybrid);
}

#[test]
fn desync2_matches_golden() {
    check_arch(Arch::Desync(2));
}

#[test]
fn desync4_matches_golden() {
    check_arch(Arch::Desync(4));
}

#[test]
fn upperbound_runs_and_diverges_from_standard() {
    let Some(tv) = load() else { return };
    let mut engine = TpEngine::new(
        tv.exec.clone(),
        &tv.weights,
        tv.tp,
        Arch::Upperbound,
        tv.batch,
        Interconnect::new(Fabric::Local),
    )
    .unwrap();
    let total = tv.prompt + tv.steps;
    let mut prefill_tokens = vec![0i32; tv.batch * tv.prompt];
    for b in 0..tv.batch {
        prefill_tokens[b * tv.prompt..(b + 1) * tv.prompt]
            .copy_from_slice(&tv.tokens[b * total..b * total + tv.prompt]);
    }
    let logits = engine
        .prefill(&prefill_tokens, tv.prompt, &vec![tv.prompt; tv.batch])
        .unwrap();
    assert!(logits.data.iter().all(|x| x.is_finite()));
    let want = expected(&tv.exec, "standard");
    let diff = max_abs_diff(&logits.data, &want[..tv.batch * tv.vocab]);
    assert!(diff > 1e-3, "upperbound should NOT match standard (diff {diff})");
}

#[test]
fn tp1_equals_tp2_standard() {
    // TP invariance needs no goldens — run it artifact-free on the native
    // backend with seeded random weights when artifacts are missing.
    let (exec, weights, prompt, batch) = match load() {
        Some(tv) => (tv.exec, tv.weights, tv.prompt, tv.batch),
        None => {
            let exec = Rc::new(Exec::native_named("tiny").unwrap());
            let weights = WeightStore::random(exec.cfg(), 99);
            (exec, weights, 16usize, 2usize)
        }
    };
    let tokens: Vec<i32> = (0..(batch * prompt) as i32).map(|i| i % 29 + 1).collect();
    let run = |tp: usize| {
        let mut e = TpEngine::new(
            exec.clone(),
            &weights,
            tp,
            Arch::Standard,
            batch,
            Interconnect::new(Fabric::Local),
        )
        .unwrap();
        e.prefill(&tokens, prompt, &vec![prompt; batch]).unwrap()
    };
    let a = run(1);
    let b = run(2);
    assert!(max_abs_diff(&a.data, &b.data) < 2e-3);
}
