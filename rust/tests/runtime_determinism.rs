//! Engine-vs-engine oracle **on the native backend**: the threaded rank
//! runtime must produce **bitwise-identical** logits to the sequential
//! reference runtime for every architecture variant — prefill plus 8
//! teacher-forced decode steps on the tiny model.
//!
//! This is the determinism contract of the rendezvous collective: partials
//! are always reduced in rank order 0..tp no matter which worker arrives
//! last, every worker issues the exact module sequence the sequential
//! scheduler would, and Upperbound's ranks rendezvous on rank 0's partial
//! so its single shared residual stream is preserved. The native executor
//! adds the second half of the contract: every kernel accumulates in a
//! fixed order, so identical inputs give identical bits on any thread.
//!
//! Runs with no `artifacts/` directory (seeded random weights; the shipped
//! test-vector weights are preferred when artifacts exist). The
//! `xla`-feature parity test at the bottom compares the two backends.

use std::rc::Rc;

use ladder_infer::comm::{Codec, Fabric, Interconnect};
use ladder_infer::engine::{KvLayout, OverlapMode, RuntimeKind, TpEngine};
use ladder_infer::model::{Arch, WeightStore};
use ladder_infer::runtime::Exec;

const PROMPT: usize = 16;
const DECODE_STEPS: usize = 8;
const WEIGHT_SEED: u64 = 0xD0D0;

fn tiny_weights(exec: &Exec) -> WeightStore {
    // identical weights for every engine in this file, artifacts or not
    if let Some(art) = exec.artifacts_opt() {
        if let Ok(flat) = art.read_f32("testvec_weights.f32") {
            if let Ok(w) = WeightStore::from_flat(&flat, art.packing().unwrap(), exec.cfg().layers)
            {
                return w;
            }
        }
    }
    WeightStore::random(exec.cfg(), WEIGHT_SEED)
}

/// Run prefill + teacher-forced decode; return every step's logits as raw
/// f32 bit patterns (so NaN-safe exact comparison is possible).
fn logits_stream(arch: Arch, runtime: RuntimeKind) -> Vec<Vec<u32>> {
    let exec = Rc::new(Exec::native_named("tiny").expect("native tiny config"));
    let weights = tiny_weights(&exec);
    let mut engine = TpEngine::with_runtime(
        exec,
        &weights,
        2,
        arch,
        2,
        Interconnect::new(Fabric::Local),
        runtime,
    )
    .unwrap();
    drive_stream(&mut engine)
}

/// Same schedule through the full constructor with an explicit collective
/// wire codec.
fn logits_stream_codec(arch: Arch, runtime: RuntimeKind, codec: Codec) -> Vec<Vec<u32>> {
    let exec = Rc::new(Exec::native_named("tiny").expect("native tiny config"));
    let weights = tiny_weights(&exec);
    let mut engine = TpEngine::with_codec(
        exec,
        &weights,
        2,
        arch,
        2,
        Interconnect::new(Fabric::Local),
        runtime,
        KvLayout::Slab,
        codec,
    )
    .unwrap();
    drive_stream(&mut engine)
}

fn drive_stream(engine: &mut TpEngine) -> Vec<Vec<u32>> {
    let tokens: Vec<i32> = (0..(2 * PROMPT) as i32).map(|i| i % 13 + 1).collect();
    let mut stream = Vec::with_capacity(DECODE_STEPS + 1);
    let logits = engine.prefill(&tokens, PROMPT, &[PROMPT, PROMPT]).unwrap();
    stream.push(logits.data.iter().map(|x| x.to_bits()).collect());
    for t in 0..DECODE_STEPS as i32 {
        let logits = engine.decode(&[t % 7 + 1, t % 5 + 2]).unwrap();
        stream.push(logits.data.iter().map(|x| x.to_bits()).collect());
    }
    stream
}

fn check_bitwise(arch: Arch) {
    let seq = logits_stream(arch, RuntimeKind::Sequential);
    let thr = logits_stream(arch, RuntimeKind::Threaded);
    assert_eq!(seq.len(), thr.len());
    for (step, (a, b)) in seq.iter().zip(&thr).enumerate() {
        assert_eq!(
            a,
            b,
            "{}: step {step} logits diverge bitwise between runtimes",
            arch.name()
        );
    }
}

#[test]
fn standard_bitwise_identical() {
    check_bitwise(Arch::Standard);
}

#[test]
fn ladder_bitwise_identical() {
    check_bitwise(Arch::Ladder);
}

#[test]
fn hybrid_bitwise_identical() {
    check_bitwise(Arch::Hybrid);
}

#[test]
fn parallel_bitwise_identical() {
    check_bitwise(Arch::Parallel);
}

#[test]
fn desync2_bitwise_identical() {
    check_bitwise(Arch::Desync(2));
}

#[test]
fn desync4_bitwise_identical() {
    check_bitwise(Arch::Desync(4));
}

#[test]
fn upperbound_bitwise_identical() {
    check_bitwise(Arch::Upperbound);
}

#[test]
fn continuous_batching_slots_bitwise_identical() {
    // prefill_slot + release_slot round-trip through worker KV caches: admit
    // slot 1 alone, decode, release, re-admit — both runtimes must agree.
    let drive = |runtime: RuntimeKind| -> Vec<u32> {
        let exec = Rc::new(Exec::native_named("tiny").expect("native tiny config"));
        let weights = tiny_weights(&exec);
        let mut engine = TpEngine::with_runtime(
            exec,
            &weights,
            2,
            Arch::Ladder,
            2,
            Interconnect::new(Fabric::Local),
            runtime,
        )
        .unwrap();
        let prompt: Vec<i32> = (0..PROMPT as i32).map(|i| i % 11 + 1).collect();
        let mut bits = Vec::new();
        let l = engine.prefill_slot(1, &prompt, PROMPT, PROMPT).unwrap();
        bits.extend(l.iter().map(|x| x.to_bits()));
        let d = engine.decode(&[0, 3]).unwrap();
        bits.extend(d.data.iter().map(|x| x.to_bits()));
        engine.release_slot(1);
        let l = engine.prefill_slot(0, &prompt, PROMPT, PROMPT).unwrap();
        bits.extend(l.iter().map(|x| x.to_bits()));
        bits
    };
    assert_eq!(
        drive(RuntimeKind::Sequential),
        drive(RuntimeKind::Threaded),
        "continuous-batching logits diverge between runtimes"
    );
}

/// Paged KV is part of the determinism contract too: chunked paged prefill
/// + page-table decode must reproduce the slab engine's logits **bitwise**,
/// on both rank runtimes (the threaded path broadcasts the page tables to
/// every worker).
#[test]
fn paged_layout_bitwise_identical_to_slab_on_both_runtimes() {
    let paged_stream = |runtime: RuntimeKind| -> Vec<Vec<u32>> {
        let exec = Rc::new(Exec::native_named("tiny").expect("native tiny config"));
        let weights = tiny_weights(&exec);
        let (page_size, pages) = (8usize, 64usize);
        let mut engine = TpEngine::with_layout(
            exec,
            &weights,
            2,
            Arch::Ladder,
            2,
            Interconnect::new(Fabric::Local),
            runtime,
            KvLayout::Paged { page_size, pages },
        )
        .unwrap();
        let max_pages = engine.kv_max_pages_per_seq();
        // static page tables: slot 0 owns pages 0.., slot 1 owns max_pages..
        let table = |slot: usize| -> Vec<u32> {
            (0..max_pages as u32).map(|i| (slot * max_pages) as u32 + i).collect()
        };
        let tokens: Vec<i32> = (0..(2 * PROMPT) as i32).map(|i| i % 13 + 1).collect();
        let mut stream = Vec::with_capacity(DECODE_STEPS + 1);
        // slot 0 prefills in two chunks (7 + 9), slot 1 in one — the final
        // chunk's logits must equal the one-shot slab prefill rows
        engine.prefill_chunk_slot(0, &tokens[..7], 0, &table(0)).unwrap();
        let row0 = engine.prefill_chunk_slot(0, &tokens[7..PROMPT], 7, &table(0)).unwrap();
        let row1 = engine
            .prefill_chunk_slot(1, &tokens[PROMPT..2 * PROMPT], 0, &table(1))
            .unwrap();
        let mut bits: Vec<u32> = row0.iter().map(|x| x.to_bits()).collect();
        bits.extend(row1.iter().map(|x| x.to_bits()));
        stream.push(bits);
        let mut tables = vec![-1i32; 2 * max_pages];
        for slot in 0..2 {
            for (i, pg) in table(slot).iter().enumerate() {
                tables[slot * max_pages + i] = *pg as i32;
            }
        }
        for t in 0..DECODE_STEPS as i32 {
            let logits = engine
                .decode_paged(&[t % 7 + 1, t % 5 + 2], &[true, true], tables.clone(), max_pages)
                .unwrap();
            stream.push(logits.data.iter().map(|x| x.to_bits()).collect());
        }
        stream
    };
    let slab = logits_stream(Arch::Ladder, RuntimeKind::Sequential);
    for runtime in [RuntimeKind::Sequential, RuntimeKind::Threaded] {
        let paged = paged_stream(runtime);
        assert_eq!(slab.len(), paged.len());
        for (step, (a, b)) in slab.iter().zip(&paged).enumerate() {
            assert_eq!(
                a,
                b,
                "paged[{}] step {step} logits diverge bitwise from the slab oracle",
                runtime.name()
            );
        }
    }
}

/// The prefix-cache reuse contract: logits computed with a **cache hit**
/// (cached prefix pages are read through the page table, only the uncached
/// suffix is prefilled) must be bitwise identical to a cold chunked
/// prefill of the whole prompt — for every architecture, on both rank
/// runtimes. Three hit shapes are covered:
///
/// * a partial hit whose suffix starts mid-page-chain at position 16 while
///   the cold run chunked at 6 (`--prefill-chunk`-style grid): the hit
///   lands mid-chunk;
/// * decode steps after the hit (shared pages stay read-only);
/// * a full-prompt hit via the copy-on-write trailing page: the shared
///   last page is duplicated with `copy_page` and only the final token is
///   re-prefilled over the copy.
fn assert_prefix_hit_bitwise(arch: Arch, runtime: RuntimeKind, overlap: OverlapMode) {
    let exec = Rc::new(Exec::native_named("tiny").expect("native tiny config"));
    let weights = tiny_weights(&exec);
    let mut engine = TpEngine::with_overlap(
        exec,
        &weights,
        2,
        arch,
        3,
        Interconnect::new(Fabric::Local),
        runtime,
        KvLayout::Paged { page_size: 8, pages: 64 },
        Codec::Fp32,
        overlap,
    )
    .unwrap();
    let prompt: Vec<i32> = (0..21).map(|i| i % 13 + 1).collect();

    // cold oracle on slot 0, chunks of 6 (6+6+6+3), pages [0,1,2,7]
    let t_cold: Vec<u32> = vec![0, 1, 2, 7];
    for (i, chunk) in prompt.chunks(6).enumerate() {
        let logits = engine.prefill_chunk_slot(0, chunk, i * 6, &t_cold).unwrap();
        if (i + 1) * 6 >= prompt.len() {
            // hit on slot 1: reuse the cold slot's first two pages (16
            // cached tokens) and prefill positions 16..21 — a start that
            // sits mid-page-chain and mid-chunk on the cold run's grid
            let t_hit: Vec<u32> = vec![0, 1, 3, 8];
            let hit = engine.prefill_chunk_slot(1, &prompt[16..], 16, &t_hit).unwrap();
            let cold: Vec<u32> = logits.iter().map(|x| x.to_bits()).collect();
            let hit: Vec<u32> = hit.iter().map(|x| x.to_bits()).collect();
            assert_eq!(cold, hit, "{}/{}: hit logits != cold", arch.name(), runtime.name());
        }
    }

    // decode: the hit slot must track the cold slot bitwise, step by step
    let max_pages = engine.kv_max_pages_per_seq();
    let mut tables = vec![-1i32; 3 * max_pages];
    for (slot, t) in [(0usize, &[0u32, 1, 2, 7]), (1, &[0, 1, 3, 8])] {
        for (i, &p) in t.iter().enumerate() {
            tables[slot * max_pages + i] = p as i32;
        }
    }
    for t in 0..4i32 {
        let tok = t % 7 + 1;
        let logits = engine
            .decode_paged(&[tok, tok, 0], &[true, true, false], tables.clone(), max_pages)
            .unwrap();
        let v = logits.shape[1];
        let row = |b: usize| -> Vec<u32> {
            logits.data[b * v..(b + 1) * v].iter().map(|x| x.to_bits()).collect()
        };
        assert_eq!(
            row(0),
            row(1),
            "{}/{}: decode step {t} diverged after the hit",
            arch.name(),
            runtime.name()
        );
    }

    // full-prompt hit (copy-on-write): prompt2 = prompt[..16] is exactly
    // the cold slot's two full pages. Cold reference on slot 2; the hit
    // reuses page 0 shared, duplicates page 1 into private page 9, and
    // re-prefills only position 15 over the copy.
    let cold2 = engine.prefill_chunk_slot(2, &prompt[..16], 0, &[4, 5]).unwrap();
    engine.release_slot(1);
    engine.copy_page(1, 9).unwrap();
    let cow = engine.prefill_chunk_slot(1, &prompt[15..16], 15, &[0, 9]).unwrap();
    let cold2: Vec<u32> = cold2.iter().map(|x| x.to_bits()).collect();
    let cow: Vec<u32> = cow.iter().map(|x| x.to_bits()).collect();
    assert_eq!(cold2, cow, "{}/{}: COW hit logits != cold", arch.name(), runtime.name());
}

const ALL_ARCHES: [Arch; 7] = [
    Arch::Standard,
    Arch::Ladder,
    Arch::Hybrid,
    Arch::Parallel,
    Arch::Desync(2),
    Arch::Desync(4),
    Arch::Upperbound,
];

#[test]
fn prefix_cache_hits_bitwise_equal_cold_prefill_sequential() {
    for arch in ALL_ARCHES {
        assert_prefix_hit_bitwise(arch, RuntimeKind::Sequential, OverlapMode::None);
    }
}

#[test]
fn prefix_cache_hits_bitwise_equal_cold_prefill_threaded() {
    for arch in ALL_ARCHES {
        assert_prefix_hit_bitwise(arch, RuntimeKind::Threaded, OverlapMode::None);
    }
}

/// Prefix-cache hits under split-batch overlap: the per-slot chunked
/// prefills stay unsplit (single-row forwards), but the batch-3 paged
/// decode after the hit is chunked 2+1 — the cold row and the hit row land
/// in *different* chunks and must still agree bitwise.
#[test]
fn prefix_cache_hits_bitwise_equal_cold_prefill_with_split_overlap() {
    for arch in ALL_ARCHES {
        for runtime in [RuntimeKind::Sequential, RuntimeKind::Threaded] {
            assert_prefix_hit_bitwise(arch, runtime, OverlapMode::Split2);
        }
    }
}

/// The disk-tier restore contract (`engine/spill.rs`): pages serialized
/// through the on-disk spill format and written back into a **fresh**
/// engine must be bitwise-indistinguishable from the cold-prefilled
/// original — the suffix prefill over the restored pages and every decode
/// step after it reproduce the cold engine's logits exactly. Anything
/// less (a float rounded through serialization, a plane ordered
/// differently, a rank swapped) shows up here as a bit flip.
fn assert_spill_roundtrip_bitwise(arch: Arch, runtime: RuntimeKind) {
    use ladder_infer::engine::SpillStore;
    let dir = std::path::PathBuf::from(env!("CARGO_TARGET_TMPDIR")).join(format!(
        "spill_determinism_{}_{}_{}",
        arch.name(),
        runtime.name(),
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let build = || {
        let exec = Rc::new(Exec::native_named("tiny").expect("native tiny config"));
        let weights = tiny_weights(&exec);
        TpEngine::with_codec(
            exec,
            &weights,
            2,
            arch,
            2,
            Interconnect::new(Fabric::Local),
            runtime,
            KvLayout::Paged { page_size: 8, pages: 64 },
            Codec::Fp32,
        )
        .unwrap()
    };
    let prompt: Vec<i32> = (0..21).map(|i| i % 13 + 1).collect();
    let table: Vec<u32> = vec![0, 1, 2];
    // cold engine: prefill the two full pages, then the suffix
    let mut cold = build();
    cold.prefill_chunk_slot(0, &prompt[..16], 0, &table).unwrap();
    let cold_suffix = cold.prefill_chunk_slot(0, &prompt[16..], 16, &table).unwrap();
    // spill both full pages through the on-disk format
    let mut store = SpillStore::open(&dir, 0, cold.kv_fingerprint()).unwrap();
    for m in 1..=2usize {
        let per_rank = cold.read_page((m - 1) as u32).unwrap();
        let wrote = store.store(&prompt[..m * 8], &per_rank).unwrap();
        assert!(wrote > 0, "{}/{}: page {m} did not spill", arch.name(), runtime.name());
    }
    drop(store);
    // fresh engine: restore the pages from disk, prefill only the suffix
    let mut warm = build();
    let mut store = SpillStore::open(&dir, 0, warm.kv_fingerprint()).unwrap();
    for m in 1..=2usize {
        let per_rank = store.load(&prompt[..m * 8]).unwrap().unwrap_or_else(|| {
            panic!("{}/{}: page {m} missing from the spill dir", arch.name(), runtime.name())
        });
        warm.write_page((m - 1) as u32, &per_rank).unwrap();
    }
    let warm_suffix = warm.prefill_chunk_slot(0, &prompt[16..], 16, &table).unwrap();
    let bits = |v: &[f32]| -> Vec<u32> { v.iter().map(|x| x.to_bits()).collect() };
    assert_eq!(
        bits(&cold_suffix),
        bits(&warm_suffix),
        "{}/{}: suffix prefill over restored pages diverges bitwise",
        arch.name(),
        runtime.name()
    );
    // decode over the restored pages must track the cold engine bitwise
    let max_pages = cold.kv_max_pages_per_seq();
    let mut tables = vec![-1i32; 2 * max_pages];
    for (i, &p) in table.iter().enumerate() {
        tables[i] = p as i32;
    }
    for t in 0..4i32 {
        let a = cold
            .decode_paged(&[t % 7 + 1, 0], &[true, false], tables.clone(), max_pages)
            .unwrap();
        let b = warm
            .decode_paged(&[t % 7 + 1, 0], &[true, false], tables.clone(), max_pages)
            .unwrap();
        assert_eq!(
            bits(&a.data),
            bits(&b.data),
            "{}/{}: decode step {t} diverges bitwise after the restore",
            arch.name(),
            runtime.name()
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn spill_tier_restores_bitwise_identical_pages_sequential() {
    for arch in ALL_ARCHES {
        assert_spill_roundtrip_bitwise(arch, RuntimeKind::Sequential);
    }
}

#[test]
fn spill_tier_restores_bitwise_identical_pages_threaded() {
    for arch in ALL_ARCHES {
        assert_spill_roundtrip_bitwise(arch, RuntimeKind::Threaded);
    }
}

/// The codec half of the determinism contract (`comm/codec.rs`): a
/// quantizing wire codec applies the same elementwise transform to each
/// partial before the same rank-order reduction on both runtimes, so the
/// threaded logits must stay bitwise-identical to the sequential oracle
/// under int8/int4 too — quantization drifts from fp32, never between
/// runtimes.
fn check_bitwise_codec(arch: Arch, codec: Codec) {
    let seq = logits_stream_codec(arch, RuntimeKind::Sequential, codec);
    let thr = logits_stream_codec(arch, RuntimeKind::Threaded, codec);
    assert_eq!(seq.len(), thr.len());
    for (step, (a, b)) in seq.iter().zip(&thr).enumerate() {
        assert_eq!(
            a,
            b,
            "{} [{}]: step {step} logits diverge bitwise between runtimes",
            arch.name(),
            codec.name()
        );
    }
}

#[test]
fn int8_codec_bitwise_identical_across_runtimes_all_arches() {
    for arch in ALL_ARCHES {
        check_bitwise_codec(arch, Codec::Int8);
    }
}

#[test]
fn int4_codec_bitwise_identical_across_runtimes_all_arches() {
    for arch in ALL_ARCHES {
        check_bitwise_codec(arch, Codec::Int4);
    }
}

/// The fp32 codec is a literal no-op on the wire: logits must be
/// bitwise-identical to the default (pre-codec) constructor path on both
/// runtimes, for every architecture.
#[test]
fn fp32_codec_bitwise_identical_to_default_path() {
    for arch in ALL_ARCHES {
        for runtime in [RuntimeKind::Sequential, RuntimeKind::Threaded] {
            assert_eq!(
                logits_stream(arch, runtime),
                logits_stream_codec(arch, runtime, Codec::Fp32),
                "{} [{}]: fp32 codec diverges from the default path",
                arch.name(),
                runtime.name()
            );
        }
    }
}

/// Drive prefill + teacher-forced decode through a split-batch overlap
/// engine at an arbitrary batch size; the oracle is the same driver with
/// `OverlapMode::None`.
fn logits_stream_overlap(
    arch: Arch,
    runtime: RuntimeKind,
    codec: Codec,
    overlap: OverlapMode,
    batch: usize,
) -> Vec<Vec<u32>> {
    let exec = Rc::new(Exec::native_named("tiny").expect("native tiny config"));
    let weights = tiny_weights(&exec);
    let mut engine = TpEngine::with_overlap(
        exec,
        &weights,
        2,
        arch,
        batch,
        Interconnect::new(Fabric::Local),
        runtime,
        KvLayout::Slab,
        codec,
        overlap,
    )
    .unwrap();
    let tokens: Vec<i32> = (0..(batch * PROMPT) as i32).map(|i| i % 13 + 1).collect();
    let lens = vec![PROMPT; batch];
    let mut stream = Vec::with_capacity(DECODE_STEPS + 1);
    let logits = engine.prefill(&tokens, PROMPT, &lens).unwrap();
    stream.push(logits.data.iter().map(|x| x.to_bits()).collect());
    for t in 0..DECODE_STEPS as i32 {
        let toks: Vec<i32> = (0..batch as i32).map(|b| (t + b) % 7 + 1).collect();
        let logits = engine.decode(&toks).unwrap();
        stream.push(logits.data.iter().map(|x| x.to_bits()).collect());
    }
    stream
}

/// The tentpole contract of split-batch overlap (`engine/overlap.rs`): a
/// chunked forward reproduces the unsplit schedule **bitwise** — every
/// architecture, on both rank runtimes. Every kernel in a block is
/// row-local, each chunk's AllReduce sums the same per-rank partials in the
/// same rank order, and chunks are concatenated back in row order before
/// the LM head.
#[test]
fn split_overlap_bitwise_identical_all_arches_both_runtimes() {
    for arch in ALL_ARCHES {
        for runtime in [RuntimeKind::Sequential, RuntimeKind::Threaded] {
            let oracle = logits_stream_overlap(arch, runtime, Codec::Fp32, OverlapMode::None, 2);
            for overlap in [OverlapMode::Split2, OverlapMode::Split4] {
                assert_eq!(
                    oracle,
                    logits_stream_overlap(arch, runtime, Codec::Fp32, overlap, 2),
                    "{} [{}/{}]: split logits diverge bitwise from the unsplit oracle",
                    arch.name(),
                    runtime.name(),
                    overlap.name()
                );
            }
        }
    }
}

/// Split chunks stay codec-block aligned on the tiny config (hidden 64 ==
/// `QUANT_BLOCK`), so the bitwise contract extends to the quantizing wire
/// codecs: each chunk's message quantizes into exactly the blocks the
/// unsplit message would.
#[test]
fn split_overlap_bitwise_identical_under_quantized_codecs() {
    for codec in [Codec::Int8, Codec::Int4] {
        for arch in ALL_ARCHES {
            for runtime in [RuntimeKind::Sequential, RuntimeKind::Threaded] {
                let oracle = logits_stream_overlap(arch, runtime, codec, OverlapMode::None, 2);
                assert_eq!(
                    oracle,
                    logits_stream_overlap(arch, runtime, codec, OverlapMode::Split4, 2),
                    "{} [{}/{}]: split4 diverges bitwise from the unsplit oracle",
                    arch.name(),
                    runtime.name(),
                    codec.name()
                );
            }
        }
    }
}

/// Batch sizes that don't divide the chunk count exercise the remainder
/// partition (leading chunks one row larger) and the degraded case where
/// split4 yields fewer than 4 chunks.
#[test]
fn split_overlap_bitwise_identical_on_uneven_batches() {
    for arch in [Arch::Standard, Arch::Ladder, Arch::Hybrid] {
        for runtime in [RuntimeKind::Sequential, RuntimeKind::Threaded] {
            let oracle = logits_stream_overlap(arch, runtime, Codec::Fp32, OverlapMode::None, 3);
            for overlap in [OverlapMode::Split2, OverlapMode::Split4] {
                assert_eq!(
                    oracle,
                    logits_stream_overlap(arch, runtime, Codec::Fp32, overlap, 3),
                    "{} [{}/{}]: uneven-batch split diverges bitwise",
                    arch.name(),
                    runtime.name(),
                    overlap.name()
                );
            }
        }
    }
}

/// Paged decode under split-batch overlap: each chunk carries its rows'
/// slice of the page tables, and the result must still equal the slab
/// oracle bitwise (chunked paged prefill is per-slot and therefore never
/// split; the batched decode path is).
#[test]
fn split_overlap_paged_decode_bitwise_identical_to_slab() {
    let paged_split_stream = |runtime: RuntimeKind| -> Vec<Vec<u32>> {
        let exec = Rc::new(Exec::native_named("tiny").expect("native tiny config"));
        let weights = tiny_weights(&exec);
        let mut engine = TpEngine::with_overlap(
            exec,
            &weights,
            2,
            Arch::Ladder,
            2,
            Interconnect::new(Fabric::Local),
            runtime,
            KvLayout::Paged { page_size: 8, pages: 64 },
            Codec::Fp32,
            OverlapMode::Split2,
        )
        .unwrap();
        let max_pages = engine.kv_max_pages_per_seq();
        let table = |slot: usize| -> Vec<u32> {
            (0..max_pages as u32).map(|i| (slot * max_pages) as u32 + i).collect()
        };
        let tokens: Vec<i32> = (0..(2 * PROMPT) as i32).map(|i| i % 13 + 1).collect();
        let mut stream = Vec::with_capacity(DECODE_STEPS + 1);
        let row0 = engine.prefill_chunk_slot(0, &tokens[..PROMPT], 0, &table(0)).unwrap();
        let row1 = engine
            .prefill_chunk_slot(1, &tokens[PROMPT..2 * PROMPT], 0, &table(1))
            .unwrap();
        let mut bits: Vec<u32> = row0.iter().map(|x| x.to_bits()).collect();
        bits.extend(row1.iter().map(|x| x.to_bits()));
        stream.push(bits);
        let mut tables = vec![-1i32; 2 * max_pages];
        for slot in 0..2 {
            for (i, pg) in table(slot).iter().enumerate() {
                tables[slot * max_pages + i] = *pg as i32;
            }
        }
        for t in 0..DECODE_STEPS as i32 {
            let logits = engine
                .decode_paged(&[t % 7 + 1, t % 5 + 2], &[true, true], tables.clone(), max_pages)
                .unwrap();
            stream.push(logits.data.iter().map(|x| x.to_bits()).collect());
        }
        stream
    };
    let slab = logits_stream(Arch::Ladder, RuntimeKind::Sequential);
    for runtime in [RuntimeKind::Sequential, RuntimeKind::Threaded] {
        let paged = paged_split_stream(runtime);
        assert_eq!(slab.len(), paged.len());
        for (step, (a, b)) in slab.iter().zip(&paged).enumerate() {
            assert_eq!(
                a,
                b,
                "split-paged[{}] step {step} diverges bitwise from the slab oracle",
                runtime.name()
            );
        }
    }
}

/// Backend parity: native logits must match the PJRT path within tolerance
/// on the tiny config. Needs `--features xla`, the real vendored xla-rs
/// toolchain, and `make artifacts` (skips with a note when absent).
#[cfg(feature = "xla")]
#[test]
fn native_matches_xla_backend_within_tolerance() {
    use ladder_infer::runtime::BackendKind;

    if ladder_infer::runtime::ArtifactDir::open_named("tiny").is_err() {
        eprintln!("skipping native-vs-xla parity: no artifacts/tiny (run `make artifacts`)");
        return;
    }
    let run = |kind: BackendKind| -> Vec<Vec<f32>> {
        let exec = Rc::new(Exec::open("tiny", kind).unwrap());
        let weights = tiny_weights(&exec);
        let mut engine = TpEngine::with_runtime(
            exec,
            &weights,
            2,
            Arch::Ladder,
            2,
            Interconnect::new(Fabric::Local),
            RuntimeKind::Sequential,
        )
        .unwrap();
        let tokens: Vec<i32> = (0..(2 * PROMPT) as i32).map(|i| i % 13 + 1).collect();
        let mut out = vec![engine.prefill(&tokens, PROMPT, &[PROMPT, PROMPT]).unwrap().data];
        for t in 0..4i32 {
            out.push(engine.decode(&[t % 7 + 1, t % 5 + 2]).unwrap().data);
        }
        out
    };
    let native = run(BackendKind::Native);
    let xla = run(BackendKind::Xla);
    for (step, (a, b)) in native.iter().zip(&xla).enumerate() {
        let diff = a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        // tiny artifacts use Pallas kernels; reduction-order differences
        // bound the agreement the same way the python goldens do
        assert!(diff < 2e-3, "step {step}: native vs xla logits diff {diff}");
    }
}
