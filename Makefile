# ladder-infer build entry points.
#
# The default (native) backend needs NO artifacts: `make artifacts` is only
# required for the artifact-backed PJRT path (`cargo build --features xla`)
# and for the golden-logit parity tests, which skip themselves when
# artifacts/ is absent.

.PHONY: build test bench artifacts clean-artifacts

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench paper_suite -- table1
	cargo bench --bench engine_hotpath -- --smoke

# AOT-export the HLO module artifacts (tiny/small/parity) via the python
# L1/L2 layer. Requires JAX; a no-op requirement for the native backend.
artifacts:
	cd python && python3 -m compile.aot --out ../artifacts

clean-artifacts:
	rm -rf artifacts
