//! Regenerate every table and figure of the paper's evaluation section from
//! the performance model (DESIGN.md maps each to its generator).
//!
//!   cargo run --release --example paper_tables            # all tables
//!   cargo run --release --example paper_tables -- --trace # + Fig 6 traces

use ladder_infer::perfmodel::tables;
use ladder_infer::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::new("paper_tables", "regenerate the paper's tables/figures")
        .flag("trace", "also dump Figure 6 chrome traces to /tmp")
        .opt("only", Some(""), "comma list: table1,table2,fig2,fig3,fig4,table6")
        .parse_env()?;
    let only = args.get("only")?;
    let want = |name: &str| only.is_empty() || only.split(',').any(|s| s == name);

    if want("table1") {
        tables::table1().print();
    }
    if want("table2") {
        tables::table2().print();
    }
    if want("fig2") {
        for t in tables::fig2() {
            t.print();
        }
    }
    if want("fig3") {
        tables::fig3().print();
    }
    if want("fig4") {
        tables::fig4().print();
        println!("\npareto-point counts per architecture: {:?}", tables::fig4_pareto_counts());
    }
    if want("table6") {
        tables::table6().print();
    }
    if want("training") {
        tables::training_speedup().print();
    }

    if args.has_flag("trace") {
        let (std_trace, ladder_trace) = tables::fig6_traces();
        std::fs::write("/tmp/fig6_standard_trace.json", std_trace.to_string())?;
        std::fs::write("/tmp/fig6_ladder_trace.json", ladder_trace.to_string())?;
        println!("\nFig 6 chrome traces written to /tmp/fig6_{{standard,ladder}}_trace.json");
    }
    Ok(())
}
