//! Post-training adaptation (paper Table 4 analog): pretrain a Standard
//! model, switch the upper half of its layers to Ladder Residual *without
//! retraining* (zero-shot — large quality drop), then retrain briefly and
//! show the recovery.
//!
//!   cargo run --release --example adapt_hybrid -- --base-steps 200 --adapt-steps 60

use ladder_infer::runtime::{BackendKind, Exec};
use ladder_infer::trainer::parity::{hybrid_adaptation, hybrid_table};
use ladder_infer::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::new("adapt_hybrid", "hybrid ladder conversion of a pretrained model")
        .opt("base-steps", Some("200"), "pretraining steps for the standard model")
        .opt("adapt-steps", Some("60"), "retraining steps after conversion")
        .opt("lr", Some("0.0015"), "peak pretraining learning rate")
        .opt("eval-batches", Some("8"), "held-out eval batches")
        .parse_env()?;

    // training graphs are xla-backend only (build with --features xla)
    let exec = Exec::open("parity", BackendKind::Xla)?;
    let report = hybrid_adaptation(
        &exec,
        args.get_usize("base-steps")?,
        args.get_usize("adapt-steps")?,
        args.get_f64("lr")? as f32,
        args.get_usize("eval-batches")?,
    )?;

    hybrid_table(&report).print();
    let drop = (report.zeroshot.perplexity / report.base.perplexity - 1.0) * 100.0;
    let recovered = (report.retrained.perplexity / report.base.perplexity - 1.0) * 100.0;
    println!(
        "\nzero-shot conversion: ppl {drop:+.1}% vs base (the paper's GSM8K 85->10 style drop)"
    );
    println!(
        "after {} retraining steps ({}% of pretraining): ppl {recovered:+.1}% vs base",
        report.adapt_steps,
        report.adapt_steps * 100 / report.base_steps.max(1)
    );
    Ok(())
}
