//! End-to-end serving driver (the mandated full-system workload): boot the
//! continuous-batching server on the `small` model, replay a Poisson
//! request trace through every architecture, and report throughput /
//! latency / comm-overlap — the real-engine counterpart of the paper's
//! benchmarks.
//!
//!   cargo run --release --example serve_e2e -- --requests 12 --tp 2

use std::rc::Rc;
use std::time::Instant;

use ladder_infer::comm::{Codec, Interconnect};
use ladder_infer::engine::{KvLayout, OverlapMode, RuntimeKind, TpEngine};
use ladder_infer::model::{Arch, WeightStore};
use ladder_infer::runtime::{BackendKind, Exec};
use ladder_infer::server::{Batcher, BatcherConfig, Request};
use ladder_infer::util::args::Args;
use ladder_infer::util::bench::Table;
use ladder_infer::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::new("serve_e2e", "end-to-end serving comparison across architectures")
        .opt("model", Some("small"), "artifact config")
        .opt("tp", Some("2"), "tensor-parallel degree")
        .opt("batch", Some("4"), "decode batch slots")
        .opt("requests", Some("12"), "requests in the trace")
        .opt("gen", Some("24"), "tokens per request")
        .opt(
            "fabric",
            Some("slow"),
            "nvlink|pcie|infiniband|local|slow (slow: ms-scale latency, proportionate to \
             CPU-testbed module times), or two_tier:<intra>:<cross>:<gpus_per_node> for a \
             hierarchical topology",
        )
        .opt(
            "overlap",
            Some("none"),
            "split-batch overlap: none|split2|split4 (chunked forwards, bitwise-exact)",
        )
        .opt("arches", Some("standard,parallel,ladder,desync2,desync4,upperbound"), "comma list")
        .opt("backend", Some("native"), "execution backend: native|xla")
        .opt(
            "page-size",
            Some("0"),
            "KV page size in tokens (0 = fixed-slot slabs; >0 = paged pool + chunked prefill)",
        )
        .opt("kv-budget-mb", Some("0"), "KV admission budget in MiB (0 = capacity only)")
        .opt("prefill-chunk", Some("16"), "paged: prompt tokens prefilled per iteration")
        .flag(
            "prefix-cache",
            "paged: reuse KV pages across requests sharing a prompt prefix (the trace then \
             draws prompts from 4 shared templates so hits actually occur)",
        )
        .parse_env()?;

    let exec =
        Rc::new(Exec::open(&args.get("model")?, BackendKind::parse(&args.get("backend")?)?)?);
    let cfg = exec.cfg().clone();
    let weights = WeightStore::random(&cfg, 42);
    let tp = args.get_usize("tp")?;
    let batch = args.get_usize("batch")?;
    let n_requests = args.get_usize("requests")?;
    let gen = args.get_usize("gen")?;
    let fabric = Interconnect::parse(&args.get("fabric")?)?;
    let overlap = OverlapMode::parse(&args.get("overlap")?)?;
    let page_size = args.get_usize("page-size")?;
    let layout = if page_size == 0 {
        KvLayout::Slab
    } else {
        let budget = args.get_usize("kv-budget-mb")? << 20;
        KvLayout::paged_from_budget(&cfg, tp, page_size, budget, batch)
    };

    println!(
        "serve_e2e: model={} ({} params) tp={tp} batch={batch} fabric={} overlap={} \
         requests={n_requests} gen={gen} kv={}",
        cfg.name,
        cfg.params,
        fabric.name(),
        overlap.name(),
        match layout {
            KvLayout::Slab => "slabs".to_string(),
            KvLayout::Paged { page_size, pages } => format!("paged({page_size}tok x {pages})"),
        },
    );

    // shared request trace: Poisson arrivals are simulated by submitting in
    // waves (the batcher is synchronous, so think "burst arrivals"). With
    // --prefix-cache the prompts share 4 system-prompt templates, the
    // workload shape the cache exists for.
    let prefix_cache = args.has_flag("prefix-cache");
    if prefix_cache && !layout.is_paged() {
        anyhow::bail!("--prefix-cache needs a paged KV layout (set --page-size > 0)");
    }
    let mut rng = Rng::new(7);
    // built only when the cache is on, so the default trace (and its
    // recorded numbers) consume exactly the RNG draws they always did
    let templates: Vec<Vec<i32>> = if prefix_cache {
        (0..4).map(|_| (0..16).map(|_| rng.below(cfg.vocab) as i32).collect()).collect()
    } else {
        Vec::new()
    };
    let prompts: Vec<Vec<i32>> = (0..n_requests)
        .map(|i| {
            if prefix_cache {
                let mut p = templates[i % templates.len()].clone();
                let tail = rng.range(4, 14);
                p.extend((0..tail).map(|_| rng.below(cfg.vocab) as i32));
                p
            } else {
                let len = rng.range(8, 30);
                (0..len).map(|_| rng.below(cfg.vocab) as i32).collect()
            }
        })
        .collect();

    let mut table = Table::new(
        "serve_e2e: real-engine serving comparison",
        &[
            "arch",
            "wall (s)",
            "tok/s",
            "ttft p50 (ms)",
            "itl p50 (ms)",
            "e2e p99 (ms)",
            "kv hw (pages)",
            "pfx hit %",
            "comm hidden %",
            "hidden pf/dec %",
            "intra/cross KB",
        ],
    );
    let mut baseline_tps = None;
    for arch_name in args.get("arches")?.split(',') {
        let arch = Arch::parse(arch_name)?;
        let engine = TpEngine::with_overlap(
            exec.clone(),
            &weights,
            tp,
            arch,
            batch,
            fabric,
            RuntimeKind::default(),
            layout,
            Codec::default(),
            overlap,
        )?;
        let config = BatcherConfig {
            kv_budget_bytes: args.get_usize("kv-budget-mb")? << 20,
            prefill_chunk: args.get_usize("prefill-chunk")?,
            prefix_cache,
            ..BatcherConfig::default()
        };
        let mut batcher = Batcher::new(engine, config);
        for (i, p) in prompts.iter().enumerate() {
            batcher.submit(Request::new(i as u64, p.clone(), gen));
        }
        let t0 = Instant::now();
        let results = batcher.run_to_completion()?;
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(results.len(), n_requests);
        let report = batcher.metrics.report(wall);
        let tps = report.get("throughput_tok_per_s")?.as_f64()?;
        let comm = batcher.engine.comm.stats();
        table.row(&[
            arch.name(),
            format!("{wall:.2}"),
            format!(
                "{tps:.1}{}",
                baseline_tps
                    .map(|b: f64| format!(" ({:+.0}%)", (tps / b - 1.0) * 100.0))
                    .unwrap_or_default()
            ),
            format!("{:.1}", report.get("ttft_p50_ms")?.as_f64()?),
            format!("{:.2}", report.get("itl_p50_ms")?.as_f64()?),
            format!("{:.1}", report.get("e2e_p99_ms")?.as_f64()?),
            match batcher.allocator() {
                Some(a) => format!("{}/{}", a.high_water(), a.total_pages()),
                None => "-".to_string(),
            },
            if prefix_cache {
                let m = &batcher.metrics;
                let prompt_tokens = m.prefix_hit_tokens + m.prefill_tokens;
                format!(
                    "{:.0}",
                    100.0 * m.prefix_hit_tokens as f64 / prompt_tokens.max(1) as f64
                )
            } else {
                "-".to_string()
            },
            format!("{:.0}", comm.hidden_fraction() * 100.0),
            format!(
                "{:.0}/{:.0}",
                comm.hidden_fraction_prefill() * 100.0,
                comm.hidden_fraction_decode() * 100.0
            ),
            format!("{}/{}", comm.bytes_intra >> 10, comm.bytes_cross >> 10),
        ]);
        if arch == Arch::Standard {
            baseline_tps = Some(tps);
        }
    }
    table.print();
    println!(
        "\n(ladder should beat standard; gaps grow as the fabric slows — try --fabric infiniband)"
    );
    Ok(())
}
