//! Full-loop e2e: **train in Rust, then serve the trained weights through
//! the TP engine** — proving the flat-vector packing, the sharding rules and
//! the serving modules all compose (python only ever ran at `make
//! artifacts` time).
//!
//! 1. train the `parity` model (ladder arch) on the synthetic corpus;
//! 2. slice the trained flat vector into per-rank shards;
//! 3. serve greedy generation on the TP=2 Ladder engine;
//! 4. verify the model has learned: the engine's continuations score far
//!    better under the corpus' Markov table than random tokens would.
//!
//!   cargo run --release --example train_then_serve -- --steps 120

use std::rc::Rc;

use ladder_infer::comm::Interconnect;
use ladder_infer::engine::{generate, Sampler, TpEngine};
use ladder_infer::model::{Arch, WeightStore};
use ladder_infer::runtime::{BackendKind, Exec};
use ladder_infer::trainer::{Corpus, Trainer};
use ladder_infer::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::new("train_then_serve", "train in rust, serve the result")
        .opt("steps", Some("120"), "training steps")
        .opt("lr", Some("0.0015"), "peak learning rate")
        .opt("arch", Some("ladder"), "architecture to train AND serve")
        .parse_env()?;
    let arch_name = args.get("arch")?;
    let steps = args.get_usize("steps")?;

    // training runs on the xla backend; the trained weights then serve on it too
    let exec = Rc::new(Exec::open("parity", BackendKind::Xla)?);
    let cfg = exec.cfg().clone();

    // -- 1. train ---------------------------------------------------------
    println!("training '{arch_name}' ({} params) for {steps} steps...", cfg.params);
    let mut trainer = Trainer::new(&exec)?;
    let mut corpus = Corpus::new(cfg.vocab, 4, 11);
    let run = trainer.run(&arch_name, steps, args.get_f64("lr")? as f32, &mut corpus, 77, 4)?;
    println!(
        "  loss {:.3} -> {:.3} | held-out ppl {:.1} (uniform would be {})",
        run.losses.first().unwrap(),
        run.losses.last().unwrap(),
        run.final_eval.perplexity,
        cfg.vocab
    );

    // -- 2. shard the trained flat vector --------------------------------
    let weights = WeightStore::from_flat(&trainer.w, exec.artifacts()?.packing()?, cfg.layers)?;

    // -- 3. serve ---------------------------------------------------------
    let arch = Arch::parse(&arch_name)?;
    let mut engine = TpEngine::new(
        exec.clone(),
        &weights,
        2,
        arch,
        2,
        Interconnect::parse("pcie")?,
    )?;
    let mut prompt_src = Corpus::new(cfg.vocab, 4, 500);
    let prompts = vec![prompt_src.sequence(12), prompt_src.sequence(12)];
    let report = generate::generate(&mut engine, &prompts, 16, &Sampler::Greedy)?;
    println!(
        "served {} tokens at {:.1} tok/s (comm hidden {:.0}%)",
        report.tokens.len() * report.tokens[0].len(),
        report.tokens_per_sec(),
        report.comm.hidden_fraction() * 100.0
    );

    // -- 4. the continuations must follow the corpus' Markov structure ----
    // score: fraction of generated tokens that are among the branching
    // candidates of their context (random tokens would land ~branching/V).
    let scorer = Corpus::new(cfg.vocab, 4, 0);
    let mut hits = 0usize;
    let mut total = 0usize;
    for (p, gen) in prompts.iter().zip(&report.tokens) {
        let mut seq = p.clone();
        seq.extend(gen);
        for w in seq.windows(2).skip(p.len().saturating_sub(1)) {
            if scorer.successors(w[0]).contains(&w[1]) {
                hits += 1;
            }
            total += 1;
        }
    }
    let frac = hits as f64 / total as f64;
    let chance = 4.0 / cfg.vocab as f64;
    println!(
        "generated tokens following the corpus structure: {:.0}% (chance {:.1}%)",
        frac * 100.0,
        chance * 100.0
    );
    // Only gate on structure-following once training has actually converged
    // (held-out ppl well below uniform); a short demo run just reports.
    if run.final_eval.perplexity < cfg.vocab as f64 / 4.0 {
        assert!(
            frac > 10.0 * chance,
            "converged model should follow the corpus structure ({frac} vs {chance})"
        );
    } else {
        println!(
            "(ppl {:.0} still far from converged — rerun with --steps 400+ to see \
             structure-following generation)",
            run.final_eval.perplexity
        );
    }
    println!("train -> shard -> serve loop OK");
    Ok(())
}
