//! Pretrain-from-scratch quality parity (paper Tables 3 and 5 analog):
//! train Standard / Parallel / Ladder (and optionally Desync-2x/4x) from the
//! same seeded init on the same synthetic-corpus stream; report held-out
//! perplexity and probe accuracy.
//!
//!   cargo run --release --example train_parity -- --steps 150
//!   cargo run --release --example train_parity -- --desync --steps 150

use ladder_infer::runtime::{BackendKind, Exec};
use ladder_infer::trainer::parity::{parity_table, pretrain_parity};
use ladder_infer::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::new("train_parity", "architecture quality-parity experiments")
        .opt("steps", Some("150"), "training steps per architecture")
        .opt("lr", Some("0.0015"), "peak learning rate")
        .opt("eval-batches", Some("8"), "held-out eval batches")
        .flag("desync", "run the desync variants too (Table 5 analog)")
        .flag(
            "ablation",
            "desync-2x placement ablation: drop attention's AR (paper's choice) vs drop MLP's",
        )
        .parse_env()?;

    // training graphs are xla-backend only (build with --features xla)
    let exec = Exec::open("parity", BackendKind::Xla)?;
    let steps = args.get_usize("steps")?;
    let lr = args.get_f64("lr")? as f32;
    let eval_batches = args.get_usize("eval-batches")?;

    let arches: Vec<&str> = if args.has_flag("ablation") {
        vec!["standard", "desync2", "desync2m"]
    } else if args.has_flag("desync") {
        vec!["standard", "desync2", "desync4"]
    } else {
        vec!["standard", "parallel", "ladder"]
    };
    println!(
        "training {:?} for {steps} steps each (model: {} params, tp=2 in-graph)",
        arches,
        exec.cfg().params
    );

    let rows = pretrain_parity(&exec, &arches, steps, lr, eval_batches)?;
    let title = if args.has_flag("ablation") {
        "§5 ablation: Desync-2x placement (desync2 drops attention's AR, desync2m drops MLP's)"
    } else if args.has_flag("desync") {
        "Table 5 analog: Desync Residual pretraining parity"
    } else {
        "Table 3 analog: pretraining parity (same data, same init, same steps)"
    };
    parity_table(title, &rows).print();

    let std_ppl = rows.iter().find(|r| r.arch == "standard").unwrap().eval.perplexity;
    for r in &rows {
        let gap = (r.eval.perplexity / std_ppl - 1.0) * 100.0;
        println!("  {}: ppl gap vs standard {gap:+.1}%", r.arch);
    }
    Ok(())
}
