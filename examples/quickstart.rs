//! Quickstart: build a TP=2 Ladder engine on the native backend (no
//! artifacts needed), generate a few tokens, and print throughput +
//! comm-overlap stats.
//!
//!   cargo run --release --example quickstart
//!   cargo run --release --example quickstart -- --backend xla   # after make artifacts

use std::rc::Rc;

use ladder_infer::comm::Interconnect;
use ladder_infer::engine::{generate, Sampler, TpEngine};
use ladder_infer::model::{Arch, WeightStore};
use ladder_infer::runtime::{BackendKind, Exec};
use ladder_infer::tokenizer::Tokenizer;
use ladder_infer::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::new("quickstart", "generate tokens with the tiny model")
        .opt("arch", Some("ladder"), "standard|ladder|parallel|desync2|desync4|upperbound")
        .opt("tp", Some("2"), "tensor-parallel degree")
        .opt("fabric", Some("pcie"), "nvlink|pcie|infiniband|local")
        .opt("backend", Some("native"), "execution backend: native|xla")
        .opt("gen", Some("24"), "tokens to generate")
        .parse_env()?;

    let arch = Arch::parse(&args.get("arch")?)?;
    let exec = Rc::new(Exec::open("tiny", BackendKind::parse(&args.get("backend")?)?)?);
    let cfg = exec.cfg().clone();
    println!(
        "model '{}': {} layers, hidden {}, vocab {} ({} params)",
        cfg.name, cfg.layers, cfg.hidden, cfg.vocab, cfg.params
    );

    // The tiny config ships seeded test weights with its artifacts; without
    // them, a seeded random init. Either way it is an untrained model, so
    // the text is gibberish — the point is the full pipeline.
    let weights = match exec.artifacts_opt() {
        Some(art) => WeightStore::from_flat(
            &art.read_f32("testvec_weights.f32")?,
            art.packing()?,
            cfg.layers,
        )?,
        None => WeightStore::random(&cfg, 42),
    };

    let tp = args.get_usize("tp")?;
    let fabric = Interconnect::parse(&args.get("fabric")?)?;
    let mut engine = TpEngine::new(exec.clone(), &weights, tp, arch, 2, fabric)?;
    println!(
        "engine: arch={} tp={tp} fabric={} backend={}",
        arch.name(),
        engine.comm.interconnect.name(),
        engine.backend_name()
    );

    let tok = Tokenizer::bytes_only(cfg.vocab);
    let prompts: Vec<Vec<i32>> = vec![
        tok.encode("ladder residual "),
        tok.encode("tensor parallel "),
    ];
    let gen_len = args.get_usize("gen")?;
    let report = generate::generate(&mut engine, &prompts, gen_len, &Sampler::Greedy)?;

    for (i, toks) in report.tokens.iter().enumerate() {
        println!("  sample {i}: {:?}", tok.decode(toks));
    }
    println!(
        "prefill {:.1}ms | decode {:.1}ms ({} steps) | {:.1} tok/s",
        report.prefill_time.as_secs_f64() * 1e3,
        report.decode_time.as_secs_f64() * 1e3,
        report.decode_steps,
        report.tokens_per_sec(),
    );
    println!(
        "comm: {} allreduces, {:.2}ms modeled, {:.2}ms exposed ({:.0}% hidden)",
        report.comm.allreduce_count,
        report.comm.modeled_total.as_secs_f64() * 1e3,
        report.comm.exposed_total.as_secs_f64() * 1e3,
        report.comm.hidden_fraction() * 100.0,
    );
    Ok(())
}
