"""Training / eval graphs for the quality-parity experiments (Tables 3/4/5).

The Rust trainer drives these AOT-compiled graphs; to keep the Rust interface
trivial, all weights live in ONE flat f32 vector. The packing table (name,
shape, offset) is emitted into the manifest so Rust can also slice a trained
vector into per-rank serving shards.

Exported graphs per architecture (standard/ladder/parallel/desync2/desync4/
hybrid):

- ``train_step``: (w, m, v, step, lr, tokens) -> (loss, w', m', v')
  one AdamW step on the next-token cross-entropy (fwd+bwd fused in-graph).
- ``eval_metrics``: (w, tokens) -> (loss_sum, correct)
  summed token NLL + greedy-argmax hits over the batch (held-out ppl and
  probe accuracy are computed Rust-side from accumulated sums).

TP semantics (including Desync's per-device residual streams) are simulated
in-graph with tp=2 shards — see archs.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import archs
from .model import ModelConfig

ADAM_B1 = 0.9
ADAM_B2 = 0.95
ADAM_EPS = 1e-8
WEIGHT_DECAY = 0.1
TRAIN_TP = 2


# ---------------------------------------------------------------------------
# flat packing
# ---------------------------------------------------------------------------


def packing_table(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Fixed (name, shape) order defining the flat weight vector layout."""
    h, f, v = cfg.hidden, cfg.ffn, cfg.vocab
    qd, kvd = cfg.q_dim, cfg.kv_dim
    table: list[tuple[str, tuple[int, ...]]] = [("emb", (v, h))]
    for i in range(cfg.layers):
        table += [
            (f"layers.{i}.attn_norm", (h,)),
            (f"layers.{i}.wq", (h, qd)),
            (f"layers.{i}.wk", (h, kvd)),
            (f"layers.{i}.wv", (h, kvd)),
            (f"layers.{i}.wo", (qd, h)),
            (f"layers.{i}.mlp_norm", (h,)),
            (f"layers.{i}.wg", (h, f)),
            (f"layers.{i}.wu", (h, f)),
            (f"layers.{i}.wd", (f, h)),
        ]
    table += [("final_norm", (h,)), ("lm", (h, v))]
    return table


def packed_size(cfg: ModelConfig) -> int:
    total = 0
    for _, shape in packing_table(cfg):
        n = 1
        for s in shape:
            n *= s
        total += n
    return total


def pack(cfg: ModelConfig, weights: dict) -> jnp.ndarray:
    parts = []
    for name, shape in packing_table(cfg):
        t = weights
        for part in name.split("."):
            t = t[int(part)] if part.isdigit() else t[part]
        assert t.shape == shape, f"{name}: {t.shape} != {shape}"
        parts.append(t.reshape(-1))
    return jnp.concatenate(parts)


def unpack(cfg: ModelConfig, vec: jnp.ndarray) -> dict:
    out: dict = {"layers": [dict() for _ in range(cfg.layers)]}
    off = 0
    for name, shape in packing_table(cfg):
        n = 1
        for s in shape:
            n *= s
        t = jax.lax.dynamic_slice_in_dim(vec, off, n).reshape(shape)
        off += n
        parts = name.split(".")
        if parts[0] == "layers":
            out["layers"][int(parts[1])][parts[2]] = t
        else:
            out[parts[0]] = t
    return out


# ---------------------------------------------------------------------------
# loss / train step / eval
# ---------------------------------------------------------------------------


def loss_fn(cfg: ModelConfig, arch: str, vec: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token cross-entropy. tokens: [B,S] int32."""
    weights = unpack(cfg, vec)
    logits = archs.forward(cfg, weights, tokens[:, :-1], arch, tp=TRAIN_TP)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def make_train_step(cfg: ModelConfig, arch: str):
    """AdamW step over the flat weight vector."""

    def train_step(w, m, v, step, lr, tokens):
        loss, grad = jax.value_and_grad(lambda vec: loss_fn(cfg, arch, vec, tokens))(w)
        step = step + 1
        m = ADAM_B1 * m + (1 - ADAM_B1) * grad
        v = ADAM_B2 * v + (1 - ADAM_B2) * grad * grad
        mhat = m / (1 - ADAM_B1**step)
        vhat = v / (1 - ADAM_B2**step)
        w = w - lr * (mhat / (jnp.sqrt(vhat) + ADAM_EPS) + WEIGHT_DECAY * w)
        return loss, w, m, v

    return train_step


def make_eval_metrics(cfg: ModelConfig, arch: str):
    """(w, tokens) -> (summed NLL over predicted tokens, argmax hits)."""

    def eval_metrics(w, tokens):
        weights = unpack(cfg, w)
        logits = archs.forward(cfg, weights, tokens[:, :-1], arch, tp=TRAIN_TP)
        targets = tokens[:, 1:]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        hits = jnp.sum((jnp.argmax(logits, axis=-1) == targets).astype(jnp.int32))
        return jnp.sum(nll), hits

    return eval_metrics
