"""Monolithic per-architecture forward passes (the semantic oracles).

Each function computes the *full* model forward for one architecture with TP
semantics simulated in-graph by explicit weight sharding: partial outputs per
shard, an explicit sum where the architecture performs an AllReduce, and
per-shard residual streams where it does not (Desync). These graphs serve as

1. the ground truth the Rust TP engine is tested against (same weights, same
   tokens => same logits), and
2. the bodies of the training / eval graphs (train.py) for the paper's
   quality-parity experiments (Tables 3, 4, 5).

Architectures (paper §3.3.1, §5):

- ``standard``   x_i   = AR(h_i(x_{i-1})) + x_{i-1}
- ``ladder``     x_i   = AR(h_i(x_{i-2})) + x_{i-1}            (paper eq. 2)
- ``parallel``   x_i   = AR(attn(n(x)) + mlp(n(x))) + x        (PaLM fusion)
- ``desync{n}``  keep every n-th AllReduce; dropped ones add the *local*
                 partial to a per-device residual. A retained AllReduce
                 carries ``partial_t + r_t / T`` so the streams re-synchronize
                 exactly at that point (our reading of paper §5 "the residual
                 stream ... is re-synchronized at the next AllReduce"; one
                 collective of unchanged message size). Dropping attention's
                 AR (keeping MLP's) follows the paper's reported choice.
- ``hybrid``     lower half standard, upper half ladder (paper §4.2).
- ``upperbound`` all AllReduces deleted (wrong numerics; speed ceiling) —
                 provided for engine tests only.

All math uses the ref kernels (pure jnp): these graphs exist for semantics
and training speed; the Pallas kernels are exercised by the per-rank serving
modules in model.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import ref
from .model import ModelConfig

ARCH_NAMES = ("standard", "ladder", "parallel", "desync2", "desync4", "hybrid", "upperbound")

# ablation variants (exported for training only): desync2m drops the *MLP*
# AllReduce instead of attention's — the paper reports drop-attention gives
# lower Wikitext perplexity (§5), which the ablation reproduces.
ABLATION_NAMES = ("desync2m",)


# ---------------------------------------------------------------------------
# weights: one pytree; shard views are created lazily per use
# ---------------------------------------------------------------------------


def init_weights(cfg: ModelConfig, seed: int = 0) -> dict:
    """Seeded init matching Llama conventions (scaled normal)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 2 + cfg.layers)
    h, f, v = cfg.hidden, cfg.ffn, cfg.vocab
    qd, kvd = cfg.q_dim, cfg.kv_dim
    std = h**-0.5

    def norm01(k, shape, scale):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(jnp.float32)

    layers = []
    for i in range(cfg.layers):
        lk = jax.random.split(ks[2 + i], 7)
        layers.append(
            dict(
                attn_norm=jnp.ones((h,), jnp.float32),
                wq=norm01(lk[0], (h, qd), std),
                wk=norm01(lk[1], (h, kvd), std),
                wv=norm01(lk[2], (h, kvd), std),
                wo=norm01(lk[3], (qd, h), std / (2 * cfg.layers) ** 0.5),
                mlp_norm=jnp.ones((h,), jnp.float32),
                wg=norm01(lk[4], (h, f), std),
                wu=norm01(lk[5], (h, f), std),
                wd=norm01(lk[6], (f, h), f**-0.5 / (2 * cfg.layers) ** 0.5),
            )
        )
    return dict(
        emb=norm01(ks[0], (v, h), 1.0),
        layers=layers,
        final_norm=jnp.ones((h,), jnp.float32),
        lm=norm01(ks[1], (h, v), std),
    )


def _shard_cols(w: jnp.ndarray, t: int, tp: int) -> jnp.ndarray:
    n = w.shape[1] // tp
    return w[:, t * n : (t + 1) * n]


def _shard_rows(w: jnp.ndarray, t: int, tp: int) -> jnp.ndarray:
    n = w.shape[0] // tp
    return w[t * n : (t + 1) * n, :]


# ---------------------------------------------------------------------------
# per-shard module partials (TP math: column-split in, row-split out)
# ---------------------------------------------------------------------------


def attn_partial(cfg: ModelConfig, lw: dict, x: jnp.ndarray, t: int, tp: int) -> jnp.ndarray:
    """Rank-t partial of the attention block (norm fused in). x: [B,S,H]."""
    b, s, h = x.shape
    d = cfg.head_dim
    hl, kvl = cfg.heads // tp, cfg.kv_heads // tp
    y = ref.rmsnorm(x, lw["attn_norm"], cfg.norm_eps)
    y2 = y.reshape(b * s, h)
    q = (y2 @ _shard_cols(lw["wq"], t, tp)).reshape(b, s, hl, d).transpose(0, 2, 1, 3)
    k = (y2 @ _shard_cols(lw["wk"], t, tp)).reshape(b, s, kvl, d).transpose(0, 2, 1, 3)
    v = (y2 @ _shard_cols(lw["wv"], t, tp)).reshape(b, s, kvl, d).transpose(0, 2, 1, 3)
    pos = jnp.arange(s, dtype=jnp.int32)
    q = ref.rope(q, pos, cfg.rope_theta)
    k = ref.rope(k, pos, cfg.rope_theta)
    o = ref.attention(q, k, v, causal=True)
    o2 = o.transpose(0, 2, 1, 3).reshape(b * s, hl * d)
    return (o2 @ _shard_rows(lw["wo"], t, tp)).reshape(b, s, h)


def mlp_partial(cfg: ModelConfig, lw: dict, x: jnp.ndarray, t: int, tp: int) -> jnp.ndarray:
    """Rank-t partial of the SwiGLU MLP block (norm fused in)."""
    b, s, h = x.shape
    y = ref.rmsnorm(x, lw["mlp_norm"], cfg.norm_eps).reshape(b * s, h)
    gate = y @ _shard_cols(lw["wg"], t, tp)
    up = y @ _shard_cols(lw["wu"], t, tp)
    act = ref.swiglu(gate, up)
    return (act @ _shard_rows(lw["wd"], t, tp)).reshape(b, s, h)


def _allreduce(partials: list[jnp.ndarray]) -> jnp.ndarray:
    """Fixed-order sum — matches the Rust collective's deterministic order."""
    acc = partials[0]
    for p in partials[1:]:
        acc = acc + p
    return acc


# ---------------------------------------------------------------------------
# architecture forwards: tokens -> logits
# ---------------------------------------------------------------------------


def forward(cfg: ModelConfig, weights: dict, tokens: jnp.ndarray, arch: str, tp: int = 2) -> jnp.ndarray:
    """Full forward: tokens [B,S] int32 -> logits [B,S,V]."""
    if arch == "standard":
        return _forward_synced(cfg, weights, tokens, tp, ladder_from=cfg.layers)
    if arch == "ladder":
        return _forward_synced(cfg, weights, tokens, tp, ladder_from=0)
    if arch == "hybrid":
        return _forward_synced(cfg, weights, tokens, tp, ladder_from=cfg.layers // 2)
    if arch == "parallel":
        return _forward_parallel(cfg, weights, tokens, tp)
    if arch == "desync2":
        return _forward_desync(cfg, weights, tokens, tp, n=2)
    if arch == "desync4":
        return _forward_desync(cfg, weights, tokens, tp, n=4)
    if arch == "desync2m":
        return _forward_desync(cfg, weights, tokens, tp, n=2, phase_shift=1)
    if arch == "upperbound":
        return _forward_upperbound(cfg, weights, tokens, tp)
    raise ValueError(f"unknown arch {arch!r}")


def _embed(weights: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(weights["emb"], tokens, axis=0)


def _head(cfg: ModelConfig, weights: dict, x: jnp.ndarray) -> jnp.ndarray:
    y = ref.rmsnorm(x, weights["final_norm"], cfg.norm_eps)
    return y @ weights["lm"]


def _forward_synced(cfg, weights, tokens, tp, ladder_from: int) -> jnp.ndarray:
    """Standard / Ladder / Hybrid share one loop.

    Layers < ladder_from run standard (residual add before the module);
    layers >= ladder_from run ladder (module sees the stale residual, the
    AllReduce result lands one module later). ladder_from==layers is pure
    standard; ==0 is pure ladder; ==layers//2 is the paper's hybrid.
    """
    x = _embed(weights, tokens)
    pend_attn = None  # ladder: reduced attn output not yet in the residual
    pend_mlp = None
    for i, lw in enumerate(weights["layers"]):
        if i >= ladder_from:
            # ladder block (paper Alg. 1): add *previous* module outputs
            if pend_attn is not None:
                x = x + pend_attn
            attn = _allreduce([attn_partial(cfg, lw, x, t, tp) for t in range(tp)])
            if pend_mlp is not None:
                x = x + pend_mlp
            mlp = _allreduce([mlp_partial(cfg, lw, x, t, tp) for t in range(tp)])
            pend_attn, pend_mlp = attn, mlp
        else:
            x = x + _allreduce([attn_partial(cfg, lw, x, t, tp) for t in range(tp)])
            x = x + _allreduce([mlp_partial(cfg, lw, x, t, tp) for t in range(tp)])
    if pend_attn is not None:
        x = x + pend_attn
    if pend_mlp is not None:
        x = x + pend_mlp
    return _head(cfg, weights, x)


def _forward_parallel(cfg, weights, tokens, tp) -> jnp.ndarray:
    """PaLM parallel attn+MLP: one shared pre-norm, one AllReduce per layer."""
    x = _embed(weights, tokens)
    for lw in weights["layers"]:
        # shared norm: reuse attn_norm for both branches (PaLM style)
        lw_shared = dict(lw, mlp_norm=lw["attn_norm"])
        partials = [
            attn_partial(cfg, lw_shared, x, t, tp) + mlp_partial(cfg, lw_shared, x, t, tp)
            for t in range(tp)
        ]
        x = x + _allreduce(partials)
    return _head(cfg, weights, x)


def _forward_desync(cfg, weights, tokens, tp, n: int, phase_shift: int = 0) -> jnp.ndarray:
    """Desync-nx: keep the last AllReduce in each group of n; drop the rest.

    Dropped AR => each device adds its local partial to its own residual.
    Retained AR => one collective carrying (partial_t + r_t / tp); the sum
    yields AR(partials) + mean(residuals), re-synchronizing all streams.
    A trailing resync is appended if the final module's AR was dropped (the
    head needs a single residual).

    ``phase_shift`` rotates which comm points are retained: 0 retains the
    MLP reduces (drops attention's — the paper's preferred placement), 1
    retains attention's instead (the ablation the paper reports as worse).
    """
    x0 = _embed(weights, tokens)
    rs = [x0 for _ in range(tp)]  # per-device residuals
    synced = True
    c = 0  # global comm-point counter (2 per layer: attn, mlp)
    for lw in weights["layers"]:
        for kind in ("attn", "mlp"):
            part = attn_partial if kind == "attn" else mlp_partial
            partials = [part(cfg, lw, rs[t], t, tp) for t in range(tp)]
            c += 1
            if (c + phase_shift) % n == 0:  # retained AllReduce: resync
                msg = [partials[t] + rs[t] / tp for t in range(tp)]
                x = _allreduce(msg)
                rs = [x for _ in range(tp)]
                synced = True
            else:  # dropped: local residual add
                rs = [rs[t] + partials[t] for t in range(tp)]
                synced = False
    if not synced:
        x = _allreduce([r / tp for r in rs])  # final resync (mean)
    else:
        x = rs[0]
    return _head(cfg, weights, x)


def _forward_upperbound(cfg, weights, tokens, tp) -> jnp.ndarray:
    """Comm deleted entirely: rank 0's partials only (wrong numerics)."""
    x = _embed(weights, tokens)
    for lw in weights["layers"]:
        x = x + attn_partial(cfg, lw, x, 0, tp)
        x = x + mlp_partial(cfg, lw, x, 0, tp)
    return _head(cfg, weights, x)
