"""Pallas SwiGLU activation kernel: silu(gate) * up, fused elementwise pass.

One VMEM tile of gate/up rows per grid step; the silu + product never
materializes an intermediate in HBM (the fusion gpt-fast gets from
torch.compile, expressed as a Pallas block schedule).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _swiglu_kernel(g_ref, u_ref, o_ref):
    g = g_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    o_ref[...] = (g * (1.0 / (1.0 + jnp.exp(-g))) * u).astype(o_ref.dtype)


def swiglu(gate: jnp.ndarray, up: jnp.ndarray, rows_per_tile: int = 8) -> jnp.ndarray:
    """silu(gate) * up over matching shapes [..., F]."""
    assert gate.shape == up.shape
    orig_shape = gate.shape
    f = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    g2 = gate.reshape(rows, f)
    u2 = up.reshape(rows, f)
    tile = min(rows_per_tile, rows)
    while rows % tile != 0:
        tile -= 1
    out = pl.pallas_call(
        _swiglu_kernel,
        grid=(rows // tile,),
        in_specs=[
            pl.BlockSpec((tile, f), lambda i: (i, 0)),
            pl.BlockSpec((tile, f), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, f), gate.dtype),
        interpret=True,
    )(g2, u2)
    return out.reshape(orig_shape)
