"""Pallas attention kernels: causal flash attention (prefill) and single-query
cache attention (decode).

TPU adaptation of the paper's CUDA setting (gpt-fast + torch.compile fused
attention): instead of a threadblock-per-(head, q-tile) schedule over shared
memory, we use a Pallas grid over (batch*q-head, q-tile) with the KV sequence
streamed through VMEM in tiles via an inner loop, maintaining the online
softmax running max/denominator in f32 — the classic flash schedule expressed
with BlockSpec. interpret=True for CPU-PJRT execution; on real TPU the same
structure tiles cleanly onto the MXU (D and KV tiles padded to 128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, kv_tile: int, scale: float, causal: bool, q_tile: int):
    """One grid step: all KV tiles for one (bh, q-tile) pair, online softmax.

    q_ref: [1, q_tile, D]; k_ref, v_ref: [1, S, D] (full KV for this bh);
    o_ref: [1, q_tile, D]. Leading unit dim is the grid-selected bh slice.
    """
    q = q_ref[0].astype(jnp.float32) * scale
    s_total = k_ref.shape[1]
    d = q_ref.shape[-1]
    qi = pl.program_id(1)  # q-tile index within the sequence

    m0 = jnp.full((q_tile, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((q_tile, 1), jnp.float32)
    acc0 = jnp.zeros((q_tile, d), jnp.float32)

    def body(t, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0], t * kv_tile, kv_tile, axis=0).astype(jnp.float32)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0], t * kv_tile, kv_tile, axis=0).astype(jnp.float32)
        logits = q @ k.T  # [q_tile, kv_tile]
        if causal:
            q_pos = qi * q_tile + jnp.arange(q_tile)[:, None]
            k_pos = t * kv_tile + jnp.arange(kv_tile)[None, :]
            logits = jnp.where(k_pos <= q_pos, logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + p @ v
        return m_new, l_new, acc_new

    num_kv_tiles = s_total // kv_tile
    if causal:
        # Only tiles that intersect the causal triangle for this q-tile.
        num_live = (qi * q_tile + q_tile + kv_tile - 1) // kv_tile
        num_live = jnp.minimum(num_live, num_kv_tiles)
        m, l, acc = jax.lax.fori_loop(0, num_live, body, (m0, l0, acc0))
    else:
        m, l, acc = jax.lax.fori_loop(0, num_kv_tiles, body, (m0, l0, acc0))
    o_ref[0] = (acc / l).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    scale: float | None = None,
    q_tile: int = 16,
    kv_tile: int = 16,
) -> jnp.ndarray:
    """Causal flash attention with GQA. q: [B,Hq,S,D]; k,v: [B,Hkv,S,D]."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if scale is None:
        scale = d**-0.5
    q_tile = min(q_tile, s)
    while s % q_tile != 0:
        q_tile -= 1
    kv_tile = min(kv_tile, s)
    while s % kv_tile != 0:
        kv_tile -= 1

    q3 = q.reshape(b * hq, s, d)
    # Expand KV to one slice per q head (GQA): index map selects kv head.
    k3 = k.reshape(b * hkv, s, d)
    v3 = v.reshape(b * hkv, s, d)

    def q_index(bh, qi):
        return (bh, qi, 0)

    def kv_index(bh, qi):
        # bh runs over b*hq; map to the owning kv head slice.
        return (bh // group, 0, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, kv_tile=kv_tile, scale=scale, causal=causal, q_tile=q_tile
        ),
        grid=(b * hq, s // q_tile),
        in_specs=[
            pl.BlockSpec((1, q_tile, d), q_index),
            pl.BlockSpec((1, s, d), kv_index),
            pl.BlockSpec((1, s, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, q_tile, d), q_index),
        out_shape=jax.ShapeDtypeStruct((b * hq, s, d), q.dtype),
        interpret=True,
    )(q3, k3, v3)
    return out.reshape(b, hq, s, d)


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, kv_tile: int, scale: float):
    """One grid step: one (b, q-head); stream the cache in tiles.

    q_ref: [1, 1, D]; k_ref, v_ref: [1, M, D]; len_ref: [1] int32 valid length.
    """
    q = q_ref[0].astype(jnp.float32) * scale  # [1, D]
    d = q_ref.shape[-1]
    length = len_ref[0]

    m0 = jnp.full((1, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((1, 1), jnp.float32)
    acc0 = jnp.zeros((1, d), jnp.float32)

    def body(t, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0], t * kv_tile, kv_tile, axis=0).astype(jnp.float32)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0], t * kv_tile, kv_tile, axis=0).astype(jnp.float32)
        logits = q @ k.T  # [1, kv_tile]
        k_pos = t * kv_tile + jnp.arange(kv_tile)[None, :]
        logits = jnp.where(k_pos < length, logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m - m_new)
        return m_new, l * alpha + jnp.sum(p, axis=-1, keepdims=True), acc * alpha + p @ v

    # Only tiles holding valid slots contribute; bound the loop by length.
    num_live = (length + kv_tile - 1) // kv_tile
    m, l, acc = jax.lax.fori_loop(0, num_live, body, (m0, l0, acc0))
    o_ref[0] = (acc / l).astype(o_ref.dtype)


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    length: jnp.ndarray | int,
    scale: float | None = None,
    kv_tile: int = 16,
) -> jnp.ndarray:
    """Single-token attention vs KV cache. q: [B,Hq,1,D]; caches [B,Hkv,M,D].

    ``length`` is a scalar or a [B] int32 vector (continuous batching: one
    valid-length per batch row; the BlockSpec routes row b's length to every
    grid step owned by batch row b).
    """
    b, hq, _, d = q.shape
    hkv = k_cache.shape[1]
    m_cache = k_cache.shape[2]
    group = hq // hkv
    if scale is None:
        scale = d**-0.5
    kv_tile = min(kv_tile, m_cache)
    while m_cache % kv_tile != 0:
        kv_tile -= 1

    q3 = q.reshape(b * hq, 1, d)
    k3 = k_cache.reshape(b * hkv, m_cache, d)
    v3 = v_cache.reshape(b * hkv, m_cache, d)
    len_arr = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))

    out = pl.pallas_call(
        functools.partial(_decode_kernel, kv_tile=kv_tile, scale=scale),
        grid=(b * hq,),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda bh: (bh, 0, 0)),
            pl.BlockSpec((1, m_cache, d), lambda bh, g=group: (bh // g, 0, 0)),
            pl.BlockSpec((1, m_cache, d), lambda bh, g=group: (bh // g, 0, 0)),
            pl.BlockSpec((1,), lambda bh, h=hq: (bh // h,)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda bh: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, 1, d), q.dtype),
        interpret=True,
    )(q3, k3, v3, len_arr)
    return out.reshape(b, hq, 1, d)
