"""Pallas rotary-embedding kernel (Llama rotate-half pairing).

Grid is over (batch*head); each step rotates a full [S, D] slice in VMEM.
cos/sin tables are precomputed on the host side of the graph (they depend
only on positions) and streamed in, so the kernel is a pure fused
multiply-add — the same structure the paper's CUDA-graph decode path uses.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rope_kernel(x_ref, cos_ref, sin_ref, o_ref):
    x = x_ref[0].astype(jnp.float32)  # [S, D] (unit leading dim = grid bh slice)
    d = x.shape[-1]
    half = d // 2
    cos = cos_ref[0]  # [S, half] f32
    sin = sin_ref[0]
    x1 = x[..., :half]
    x2 = x[..., half:]
    o_ref[0] = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(o_ref.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """Apply rotary embedding. x: [B,H,S,D] (D even).

    positions: [S] int32 (shared, prefill) or [B,S] (per-row, decode). The
    cos/sin tables are computed graph-side; the kernel is the fused rotate.
    """
    b, h, s, d = x.shape
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    pos2 = jnp.broadcast_to(
        positions.astype(jnp.float32).reshape((-1, s)), (b if positions.ndim == 2 else 1, s)
    )
    angles = pos2[:, :, None] * freqs[None, None, :]  # [Bp, S, half]
    bp = angles.shape[0]
    cos = jnp.cos(angles)
    sin = jnp.sin(angles)
    x3 = x.reshape(b * h, s, d)

    def tab_index(i, h=h, bp=bp):
        # Shared table (bp=1) or per-batch-row table (bp=b).
        return (0, 0, 0) if bp == 1 else (i // h, 0, 0)

    out = pl.pallas_call(
        _rope_kernel,
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, half), tab_index),
            pl.BlockSpec((1, s, half), tab_index),
        ],
        out_specs=pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), x.dtype),
        interpret=True,
    )(x3, cos, sin)
    return out.reshape(b, h, s, d)
