"""Pallas RMSNorm kernel.

TPU adaptation of the fused CUDA layernorm kernels the paper's gpt-fast
baseline relies on: each grid step owns a tile of rows resident in VMEM and
performs the full reduction + scale in one pass (one HBM read, one HBM write
per element). interpret=True so the lowered HLO runs on the CPU PJRT client.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # [rows_tile, H]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * (var + eps) ** -0.5
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5, rows_per_tile: int = 8) -> jnp.ndarray:
    """RMSNorm over the last axis. x: [..., H]; w: [H].

    Grid is over row tiles; the full hidden dim stays in VMEM (H fits easily
    for every config we export: H<=8192 rows of f32 = 32KiB/row).
    """
    orig_shape = x.shape
    h = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, h)
    # Pick the largest tile <= rows_per_tile dividing rows, so any row count works.
    tile = min(rows_per_tile, rows)
    while rows % tile != 0:
        tile -= 1
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // tile,),
        in_specs=[
            pl.BlockSpec((tile, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, h), x.dtype),
        interpret=True,
    )(x2, w)
    return out.reshape(orig_shape)
