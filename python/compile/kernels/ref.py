"""Pure-jnp reference oracles for every Pallas kernel (L1 correctness signal).

Each function here is the semantic definition of the corresponding kernel in
this package. pytest (python/tests/test_kernels.py) sweeps shapes/dtypes with
hypothesis and asserts allclose(kernel, ref). The L2 model can also be lowered
against these refs (``kernels="ref"``) for large sweep configs where
interpret-mode Pallas while-loops would dominate CPU time.
"""

from __future__ import annotations

import jax.numpy as jnp


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm over the last axis: x / rms(x) * w, computed in f32."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * (var + eps) ** -0.5
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """Rotary position embedding, Llama-style rotate-half pairing.

    x: [B, H, S, D] with D even. positions: [S] int32 (shared across the
    batch, prefill) or [B, S] (per-row positions, continuous-batching decode).
    Pairs channel d with channel d + D/2.
    """
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., :, None] * freqs[None, :]
    if positions.ndim == 1:
        cos = jnp.cos(angles)[None, None]  # [1,1,S,half]
        sin = jnp.sin(angles)[None, None]
    else:
        cos = jnp.cos(angles)[:, None]  # [B,1,S,half]
        sin = jnp.sin(angles)[:, None]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    scale: float | None = None,
) -> jnp.ndarray:
    """Multi-head attention with GQA. q: [B,Hq,S,D]; k,v: [B,Hkv,S,D]."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if scale is None:
        scale = d**-0.5
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = softmax(logits)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    length: jnp.ndarray | int,
    scale: float | None = None,
) -> jnp.ndarray:
    """Single-token attention against a KV cache.

    q: [B,Hq,1,D]; caches: [B,Hkv,M,D]; length: number of valid cache slots —
    either a scalar (all rows) or a [B] vector (continuous batching: each
    batch row has its own sequence length). Positions >= length are masked.
    """
    b, hq, _, d = q.shape
    hkv = k_cache.shape[1]
    m = k_cache.shape[2]
    group = hq // hkv
    if scale is None:
        scale = d**-0.5
    k = jnp.repeat(k_cache, group, axis=1)
    v = jnp.repeat(v_cache, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    pos = jnp.arange(m)
    length = jnp.broadcast_to(jnp.asarray(length), (b,))
    mask = pos[None, None, None, :] < length[:, None, None, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = softmax(logits)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def swiglu(gate: jnp.ndarray, up: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU activation: silu(gate) * up, f32 internally."""
    g = gate.astype(jnp.float32)
    return (g * sigmoid(g) * up.astype(jnp.float32)).astype(gate.dtype)


def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain matmul with f32 accumulation: [M,K] @ [K,N] -> [M,N]."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


# -- small numerics helpers (kept explicit so the oracles have zero magic) ----


def softmax(logits: jnp.ndarray) -> jnp.ndarray:
    m = jnp.max(logits, axis=-1, keepdims=True)
    # Guard fully-masked rows (all -inf): shift by 0 there instead of nan.
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.exp(logits - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def sigmoid(x: jnp.ndarray) -> jnp.ndarray:
    return 1.0 / (1.0 + jnp.exp(-x))
