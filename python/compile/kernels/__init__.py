"""L1 Pallas kernels (interpret=True) + pure-jnp oracles (ref).

``get_kernels(flavor)`` returns the kernel namespace the L2 model uses:
``"pallas"`` (default artifact set; Pallas interpret-mode kernels) or
``"ref"`` (pure-jnp oracles, used for large sweep configs).
"""

from types import SimpleNamespace

from . import ref
from .attention import decode_attention, flash_attention
from .matmul import matmul
from .rmsnorm import rmsnorm
from .rope import rope
from .swiglu import swiglu

__all__ = [
    "ref",
    "flash_attention",
    "decode_attention",
    "matmul",
    "rmsnorm",
    "rope",
    "swiglu",
    "get_kernels",
]


def get_kernels(flavor: str):
    if flavor == "pallas":
        return SimpleNamespace(
            rmsnorm=rmsnorm,
            rope=rope,
            attention=flash_attention,
            decode_attention=decode_attention,
            swiglu=swiglu,
            matmul=matmul,
        )
    if flavor == "ref":
        return SimpleNamespace(
            rmsnorm=ref.rmsnorm,
            rope=ref.rope,
            attention=ref.attention,
            decode_attention=ref.decode_attention,
            swiglu=ref.swiglu,
            matmul=ref.matmul,
        )
    raise ValueError(f"unknown kernel flavor: {flavor!r} (want 'pallas' or 'ref')")
