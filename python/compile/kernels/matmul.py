"""Pallas tiled matmul kernel with f32 accumulation.

MXU-oriented schedule: grid over (M-tiles, N-tiles), K streamed through VMEM
in tiles with an f32 accumulator — the TPU counterpart of the paper's
tensor-core GEMMs. Tile sizes shrink automatically for the tiny export
configs; on real TPU they'd be fixed at 128 multiples.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref, *, k_tile: int):
    k_total = a_ref.shape[-1]
    acc = jnp.zeros((a_ref.shape[0], b_ref.shape[-1]), jnp.float32)

    def body(t, acc):
        a = jax.lax.dynamic_slice_in_dim(a_ref[...], t * k_tile, k_tile, axis=1).astype(jnp.float32)
        b = jax.lax.dynamic_slice_in_dim(b_ref[...], t * k_tile, k_tile, axis=0).astype(jnp.float32)
        return acc + a @ b

    acc = jax.lax.fori_loop(0, k_total // k_tile, body, acc)
    o_ref[...] = acc.astype(o_ref.dtype)


def _pick_tile(n: int, want: int) -> int:
    t = min(want, n)
    while n % t != 0:
        t -= 1
    return t


def matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    m_tile: int = 32,
    n_tile: int = 32,
    k_tile: int = 32,
) -> jnp.ndarray:
    """[M,K] @ [K,N] -> [M,N] with f32 accumulation."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    m_tile = _pick_tile(m, m_tile)
    n_tile = _pick_tile(n, n_tile)
    k_tile = _pick_tile(k, k_tile)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_tile=k_tile),
        grid=(m // m_tile, n // n_tile),
        in_specs=[
            pl.BlockSpec((m_tile, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, n_tile), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((m_tile, n_tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)
