"""L2: the Llama-style transformer, written per-TP-rank and split at every
AllReduce edge.

The paper's whole point is that the architecture (Standard vs Ladder vs
Parallel vs Desync) differs only in *when* the AllReduce results re-enter the
residual stream. We therefore export the model as a small set of HLO modules
whose boundaries are exactly the communication points; the Rust coordinator
(L3) owns the residual stream, the collectives, and the per-architecture
schedule (paper Alg. 1). One executable per (module, phase) is shared across
all layers — only the weight buffers differ per layer.

Modules (all per-rank; shapes in the manifest):

- ``embed``          tokens[B,S] i32, emb[V,H]                    -> h[B,S,H]
- ``attn_prefill``   x[B,S,H], nw[H], wq,wk,wv,wo shards,
                     kc,vc[B,KVl,M,D], pos0[]                     -> (partial[B,S,H], kc', vc')
- ``attn_decode``    x[B,1,H], nw, shards, kc,vc, lens[B]         -> (partial[B,1,H], kc', vc')
- ``mlp``            x[B,S,H], nw[H], wg,wu[H,Fl], wd[Fl,H]       -> partial[B,S,H]
- ``fused_prefill``  Parallel-attn-MLP: one shared norm, attn+mlp
                     partials summed                              -> (partial, kc', vc')
- ``fused_decode``   likewise at S=1
- ``lm_head``        x[B,H], nw[H], wlm[H,Vl]                     -> logits[B,Vl]

Suffix ``l`` = local (TP-sharded) dim: Hql = Hq/tp heads, Fl = F/tp,
Vl = V/tp. Residual adds and AllReduces are NOT in these graphs — Rust does
them, which is what lets the same compiled modules serve every architecture.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp

from .kernels import get_kernels


@dataclass(frozen=True)
class ModelConfig:
    """Llama-style transformer configuration (full, unsharded sizes)."""

    name: str = "tiny"
    vocab: int = 256
    hidden: int = 64
    layers: int = 4
    heads: int = 4
    kv_heads: int = 2
    head_dim: int = 16
    ffn: int = 192
    max_seq: int = 128
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    kernels: str = "pallas"  # "pallas" | "ref"
    dtype: str = "f32"

    @property
    def q_dim(self) -> int:
        return self.heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.kv_heads * self.head_dim

    def shard(self, tp: int) -> "ShardConfig":
        assert self.heads % tp == 0, f"heads {self.heads} % tp {tp} != 0"
        assert self.kv_heads % tp == 0, f"kv_heads {self.kv_heads} % tp {tp} != 0"
        assert self.ffn % tp == 0 and self.vocab % tp == 0
        return ShardConfig(self, tp)

    def params(self) -> int:
        """Total parameter count (embedding + blocks + head)."""
        h, f = self.hidden, self.ffn
        per_layer = h * (self.q_dim + 2 * self.kv_dim) + self.q_dim * h + 3 * h * f + 2 * h
        return self.vocab * h * 2 + self.layers * per_layer + h


@dataclass(frozen=True)
class ShardConfig:
    """Per-rank view of a ModelConfig under TP sharding."""

    model: ModelConfig
    tp: int

    @property
    def heads_l(self) -> int:
        return self.model.heads // self.tp

    @property
    def kv_heads_l(self) -> int:
        return self.model.kv_heads // self.tp

    @property
    def ffn_l(self) -> int:
        return self.model.ffn // self.tp

    @property
    def vocab_l(self) -> int:
        return self.model.vocab // self.tp

    @property
    def q_dim_l(self) -> int:
        return self.heads_l * self.model.head_dim

    @property
    def kv_dim_l(self) -> int:
        return self.kv_heads_l * self.model.head_dim


# ---------------------------------------------------------------------------
# module builders — each returns a jit-able fn of concrete example shapes
# ---------------------------------------------------------------------------


def make_embed(cfg: ModelConfig):
    def embed(tokens, emb_w):
        return jnp.take(emb_w, tokens, axis=0)

    return embed


def _project(K, x2, w):
    """[R,H] @ [H,N] with the kernel-flavored matmul."""
    return K.matmul(x2, w)


def make_attn_prefill(sc: ShardConfig):
    """Prefill attention for one layer shard.

    x: [B,S,H] residual input (already summed/reduced by Rust);
    returns the rank-local partial output plus updated caches. Cache slots
    [0,S) are written; rope positions are 0..S-1.
    """
    cfg = sc.model
    K = get_kernels(cfg.kernels)

    def attn_prefill(x, norm_w, wq, wk, wv, wo, k_cache, v_cache):
        b, s, h = x.shape
        d = cfg.head_dim
        y = K.rmsnorm(x, norm_w, cfg.norm_eps)
        y2 = y.reshape(b * s, h)
        q = _project(K, y2, wq).reshape(b, s, sc.heads_l, d).transpose(0, 2, 1, 3)
        k = _project(K, y2, wk).reshape(b, s, sc.kv_heads_l, d).transpose(0, 2, 1, 3)
        v = _project(K, y2, wv).reshape(b, s, sc.kv_heads_l, d).transpose(0, 2, 1, 3)
        positions = jnp.arange(s, dtype=jnp.int32)
        q = K.rope(q, positions, cfg.rope_theta)
        k = K.rope(k, positions, cfg.rope_theta)
        attn = K.attention(q, k, v, causal=True)
        out = attn.transpose(0, 2, 1, 3).reshape(b * s, sc.q_dim_l)
        partial = _project(K, out, wo).reshape(b, s, h)
        k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, 0, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, 0, 0, 0))
        return partial, k_cache, v_cache

    return attn_prefill


def _write_cache_rows(cache, new, lens):
    """Per-row cache append: cache[b,:,lens[b],:] = new[b,:,0,:]."""

    def write_one(c, n, p):
        # c: [KVl,M,D], n: [KVl,1,D], p: scalar
        return jax.lax.dynamic_update_slice(c, n, (0, p, 0))

    return jax.vmap(write_one)(cache, new, lens)


def make_attn_decode(sc: ShardConfig):
    """Single-token decode attention for one layer shard.

    lens[B]: current sequence length per row (also the write position and the
    rope position of the new token).
    """
    cfg = sc.model
    K = get_kernels(cfg.kernels)

    def attn_decode(x, norm_w, wq, wk, wv, wo, k_cache, v_cache, lens):
        b, s, h = x.shape  # s == 1
        d = cfg.head_dim
        y = K.rmsnorm(x, norm_w, cfg.norm_eps)
        y2 = y.reshape(b, h)
        q = _project(K, y2, wq).reshape(b, 1, sc.heads_l, d).transpose(0, 2, 1, 3)
        k = _project(K, y2, wk).reshape(b, 1, sc.kv_heads_l, d).transpose(0, 2, 1, 3)
        v = _project(K, y2, wv).reshape(b, 1, sc.kv_heads_l, d).transpose(0, 2, 1, 3)
        positions = lens.reshape(b, 1)
        q = K.rope(q, positions, cfg.rope_theta)
        k = K.rope(k, positions, cfg.rope_theta)
        k_cache = _write_cache_rows(k_cache, k, lens)
        v_cache = _write_cache_rows(v_cache, v, lens)
        attn = K.decode_attention(q, k_cache, v_cache, lens + 1)
        out = attn.transpose(0, 2, 1, 3).reshape(b, sc.q_dim_l)
        partial = _project(K, out, wo).reshape(b, 1, h)
        return partial, k_cache, v_cache

    return attn_decode


def make_mlp(sc: ShardConfig):
    """SwiGLU MLP partial for one layer shard (norm fused in)."""
    cfg = sc.model
    K = get_kernels(cfg.kernels)

    def mlp(x, norm_w, w_gate, w_up, w_down):
        b, s, h = x.shape
        y = K.rmsnorm(x, norm_w, cfg.norm_eps).reshape(b * s, h)
        gate = _project(K, y, w_gate)
        up = _project(K, y, w_up)
        act = K.swiglu(gate, up)
        return _project(K, act, w_down).reshape(b, s, h)

    return mlp


def make_fused_prefill(sc: ShardConfig):
    """Parallel-attn-MLP (PaLM) prefill: one shared norm, summed partials.

    This is the paper's 'Parallel' baseline — halves the AllReduce count by
    emitting a single partial per layer.
    """
    cfg = sc.model
    K = get_kernels(cfg.kernels)
    attn_fn = make_attn_prefill(sc)
    mlp_fn = make_mlp(sc)

    def fused(x, norm_w, wq, wk, wv, wo, w_gate, w_up, w_down, k_cache, v_cache):
        # Attention path (reuses the attn builder's norm — same norm weights,
        # PaLM style single pre-norm for both branches).
        attn_partial, k_cache, v_cache = attn_fn(x, norm_w, wq, wk, wv, wo, k_cache, v_cache)
        mlp_partial = mlp_fn(x, norm_w, w_gate, w_up, w_down)
        return attn_partial + mlp_partial, k_cache, v_cache

    return fused


def make_fused_decode(sc: ShardConfig):
    cfg = sc.model
    attn_fn = make_attn_decode(sc)
    mlp_fn = make_mlp(sc)

    def fused(x, norm_w, wq, wk, wv, wo, w_gate, w_up, w_down, k_cache, v_cache, lens):
        attn_partial, k_cache, v_cache = attn_fn(x, norm_w, wq, wk, wv, wo, k_cache, v_cache, lens)
        mlp_partial = mlp_fn(x, norm_w, w_gate, w_up, w_down)
        return attn_partial + mlp_partial, k_cache, v_cache

    return fused


def make_lm_head(sc: ShardConfig):
    """Final norm + vocab-sharded LM head. Rust AllGathers the vocab shards."""
    cfg = sc.model
    K = get_kernels(cfg.kernels)

    def lm_head(x, norm_w, w_lm):
        y = K.rmsnorm(x, norm_w, cfg.norm_eps)
        return K.matmul(y, w_lm)

    return lm_head


# ---------------------------------------------------------------------------
# config registry — the sizes we export + the paper's size table (perf model)
# ---------------------------------------------------------------------------

CONFIGS: dict[str, ModelConfig] = {
    # tests + quickstart: small enough that pallas interpret mode is snappy
    "tiny": ModelConfig(
        name="tiny", vocab=256, hidden=64, layers=4, heads=4, kv_heads=2,
        head_dim=16, ffn=192, max_seq=128, kernels="pallas",
    ),
    # serving e2e: big enough that module exec time dominates dispatch
    "small": ModelConfig(
        name="small", vocab=2048, hidden=256, layers=8, heads=8, kv_heads=4,
        head_dim=32, ffn=768, max_seq=320, kernels="ref",
    ),
    # trainer parity experiments (Tables 3/4/5 analogs)
    "parity": ModelConfig(
        name="parity", vocab=512, hidden=128, layers=6, heads=4, kv_heads=4,
        head_dim=32, ffn=384, max_seq=128, kernels="ref",
    ),
}
