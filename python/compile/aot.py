"""AOT exporter: lower every L2 module to HLO text + write the manifest.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the rust `xla` crate) rejects; the text parser reassigns
ids and round-trips cleanly.

Layout:

    artifacts/<config>/manifest.json
    artifacts/<config>/<module>.hlo.txt

Module naming: ``<kind>__tp<T>__b<B>__s<S>`` (serving) and
``train_<arch>`` / ``eval_<arch>`` (parity training). ``make artifacts`` is
incremental: a content stamp of the compile/ sources + export parameters
skips re-export when nothing changed.

Run from python/:  python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import archs, model, train
from .model import CONFIGS, ModelConfig


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _spec_json(s: jax.ShapeDtypeStruct) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


class Exporter:
    def __init__(self, out_dir: str, cfg: ModelConfig):
        self.out_dir = os.path.join(out_dir, cfg.name)
        os.makedirs(self.out_dir, exist_ok=True)
        self.cfg = cfg
        self.modules: dict[str, dict] = {}

    def export(self, name: str, fn, specs: list, arg_names: list[str]):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        out_shape = jax.eval_shape(fn, *specs)
        outs = jax.tree_util.tree_leaves(out_shape)
        self.modules[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [dict(_spec_json(s), name=n) for n, s in zip(arg_names, specs)],
            "outputs": [_spec_json(s) for s in outs],
        }
        print(f"  [{self.cfg.name}] {name}: {len(text)//1024}KiB")

    def write_manifest(self, extra: dict):
        cfg = self.cfg
        table = train.packing_table(cfg)
        offsets = []
        off = 0
        for name, shape in table:
            n = 1
            for s in shape:
                n *= s
            offsets.append({"name": name, "shape": list(shape), "offset": off})
            off += n
        manifest = {
            "config": {
                "name": cfg.name, "vocab": cfg.vocab, "hidden": cfg.hidden,
                "layers": cfg.layers, "heads": cfg.heads, "kv_heads": cfg.kv_heads,
                "head_dim": cfg.head_dim, "ffn": cfg.ffn, "max_seq": cfg.max_seq,
                "rope_theta": cfg.rope_theta, "norm_eps": cfg.norm_eps,
                "kernels": cfg.kernels, "params": cfg.params(),
            },
            "packing": {"total": off, "tensors": offsets},
            "modules": self.modules,
            **extra,
        }
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as fh:
            json.dump(manifest, fh, indent=1)


def export_serving(ex: Exporter, tps: list[int], batches: list[int], buckets: list[int]):
    """Per-rank serving modules, split at every AllReduce edge."""
    cfg = ex.cfg
    h, d, m_cache, v = cfg.hidden, cfg.head_dim, cfg.max_seq, cfg.vocab

    for b in batches:
        for s in buckets:
            ex.export(
                f"embed__b{b}__s{s}", model.make_embed(cfg),
                [i32(b, s), f32(v, h)], ["tokens", "emb"],
            )
        ex.export(
            f"embed__b{b}__s1", model.make_embed(cfg),
            [i32(b, 1), f32(v, h)], ["tokens", "emb"],
        )

    for tp in tps:
        sc = cfg.shard(tp)
        qdl, kvl, fl, vl = sc.q_dim_l, sc.kv_heads_l, sc.ffn_l, sc.vocab_l
        kvdl = sc.kv_dim_l
        for b in batches:
            cache = f32(b, kvl, m_cache, d)
            # prefill modules per bucket
            for s in buckets:
                ex.export(
                    f"attn_prefill__tp{tp}__b{b}__s{s}", model.make_attn_prefill(sc),
                    [f32(b, s, h), f32(h), f32(h, qdl), f32(h, kvdl), f32(h, kvdl),
                     f32(qdl, h), cache, cache],
                    ["x", "norm_w", "wq", "wk", "wv", "wo", "k_cache", "v_cache"],
                )
                ex.export(
                    f"mlp__tp{tp}__b{b}__s{s}", model.make_mlp(sc),
                    [f32(b, s, h), f32(h), f32(h, fl), f32(h, fl), f32(fl, h)],
                    ["x", "norm_w", "w_gate", "w_up", "w_down"],
                )
                ex.export(
                    f"fused_prefill__tp{tp}__b{b}__s{s}", model.make_fused_prefill(sc),
                    [f32(b, s, h), f32(h), f32(h, qdl), f32(h, kvdl), f32(h, kvdl),
                     f32(qdl, h), f32(h, fl), f32(h, fl), f32(fl, h), cache, cache],
                    ["x", "norm_w", "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
                     "k_cache", "v_cache"],
                )
            # decode modules (S=1)
            ex.export(
                f"attn_decode__tp{tp}__b{b}", model.make_attn_decode(sc),
                [f32(b, 1, h), f32(h), f32(h, qdl), f32(h, kvdl), f32(h, kvdl),
                 f32(qdl, h), cache, cache, i32(b)],
                ["x", "norm_w", "wq", "wk", "wv", "wo", "k_cache", "v_cache", "lens"],
            )
            ex.export(
                f"mlp__tp{tp}__b{b}__s1", model.make_mlp(sc),
                [f32(b, 1, h), f32(h), f32(h, fl), f32(h, fl), f32(fl, h)],
                ["x", "norm_w", "w_gate", "w_up", "w_down"],
            )
            ex.export(
                f"fused_decode__tp{tp}__b{b}", model.make_fused_decode(sc),
                [f32(b, 1, h), f32(h), f32(h, qdl), f32(h, kvdl), f32(h, kvdl),
                 f32(qdl, h), f32(h, fl), f32(h, fl), f32(fl, h), cache, cache, i32(b)],
                ["x", "norm_w", "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
                 "k_cache", "v_cache", "lens"],
            )
            ex.export(
                f"lm_head__tp{tp}__b{b}", model.make_lm_head(sc),
                [f32(b, h), f32(h), f32(h, vl)],
                ["x", "norm_w", "w_lm"],
            )
    return {"tps": tps, "batches": batches, "buckets": buckets}


def export_training(ex: Exporter, arches: list[str], train_b: int, train_s: int,
                    eval_b: int, eval_s: int):
    """Parity-experiment graphs: AdamW train step + eval metrics per arch."""
    cfg = ex.cfg
    n = train.packed_size(cfg)
    for arch in arches:
        ex.export(
            f"train_{arch}", train.make_train_step(cfg, arch),
            [f32(n), f32(n), f32(n), i32(), f32(), i32(train_b, train_s)],
            ["w", "m", "v", "step", "lr", "tokens"],
        )
        ex.export(
            f"eval_{arch}", train.make_eval_metrics(cfg, arch),
            [f32(n), i32(eval_b, eval_s)],
            ["w", "tokens"],
        )
    # seeded initial weights, shipped flat so Rust starts from the same point
    w0 = train.pack(cfg, archs.init_weights(cfg, seed=0))
    import numpy as np

    np.asarray(w0, dtype=np.float32).tofile(os.path.join(ex.out_dir, "init_weights.f32"))
    return {
        "training": {
            "arches": arches, "train_batch": train_b, "train_seq": train_s,
            "eval_batch": eval_b, "eval_seq": eval_s, "train_tp": train.TRAIN_TP,
            "init_weights": "init_weights.f32",
        }
    }


def export_testvectors(ex: Exporter, tp: int, batch: int, prompt: int, steps: int):
    """Golden vectors for the Rust engine integration tests.

    For each architecture: teacher-forced logits for the prefill and `steps`
    decode steps, computed by the python SimEngine (the executable L3 spec,
    ref kernels) on seeded weights/tokens. Rust runs the exported HLO modules
    with its own scheduler and must match to kernel tolerance.
    """
    import numpy as np

    from . import engine_sim, train
    from .archs import ARCH_NAMES, init_weights

    cfg = ex.cfg
    weights = init_weights(cfg, seed=0)
    ref_cfg = cfg if cfg.kernels == "ref" else model.ModelConfig(**{**cfg.__dict__, "kernels": "ref"})
    np.asarray(train.pack(cfg, weights), dtype=np.float32).tofile(
        os.path.join(ex.out_dir, "testvec_weights.f32")
    )
    rng = np.random.default_rng(99)
    seq = rng.integers(0, cfg.vocab, (batch, prompt + steps)).astype(np.int32)
    seq.tofile(os.path.join(ex.out_dir, "testvec_tokens.i32"))

    arches = [a for a in ARCH_NAMES if a != "upperbound"]
    for arch in arches:
        eng = engine_sim.SimEngine(ref_cfg, weights, tp=tp, arch=arch, batch=batch)
        outs = [np.asarray(eng.prefill(jnp.asarray(seq[:, :prompt])))]
        for t in range(steps):
            lens = jnp.full((batch,), prompt + t, jnp.int32)
            outs.append(np.asarray(eng.decode(jnp.asarray(seq[:, prompt + t : prompt + t + 1]), lens)))
        np.stack(outs).astype(np.float32).tofile(
            os.path.join(ex.out_dir, f"testvec_logits_{arch}.f32")
        )
        print(f"  [{cfg.name}] testvec {arch}: {len(outs)} step logits")
    return {
        "testvec": {
            "tp": tp, "batch": batch, "prompt": prompt, "steps": steps,
            "weights": "testvec_weights.f32", "tokens": "testvec_tokens.i32",
            "arches": arches,
        }
    }


def export_tiny(ex: Exporter):
    extra = export_serving(ex, tps=[1, 2], batches=[1, 2], buckets=[16, 32])
    extra.update(export_testvectors(ex, tp=2, batch=2, prompt=16, steps=4))
    return extra


EXPORTS = {
    "tiny": export_tiny,
    "small": lambda ex: export_serving(ex, tps=[1, 2, 4], batches=[1, 4], buckets=[32, 128]),
    "parity": lambda ex: export_parity(ex),
}


def export_parity(ex: Exporter):
    """Training graphs (incl. the desync-placement ablation) + serving
    modules, so a Rust-trained parity model can be served by the TP engine
    (examples/train_then_serve.rs)."""
    extra = export_serving(ex, tps=[1, 2], batches=[1, 2], buckets=[16, 32])
    extra.update(
        export_training(
            ex,
            arches=["standard", "ladder", "parallel", "desync2", "desync4", "hybrid", "desync2m"],
            train_b=8, train_s=64, eval_b=16, eval_s=64,
        )
    )
    return extra


def _stamp(names: list[str]) -> str:
    h = hashlib.sha256()
    src_dir = os.path.dirname(os.path.abspath(__file__))
    for fname in sorted(os.listdir(src_dir)) + sorted(os.listdir(os.path.join(src_dir, "kernels"))):
        path = os.path.join(src_dir, fname)
        if os.path.isfile(path) and fname.endswith(".py"):
            h.update(open(path, "rb").read())
        kpath = os.path.join(src_dir, "kernels", fname)
        if os.path.isfile(kpath) and fname.endswith(".py"):
            h.update(open(kpath, "rb").read())
    h.update(",".join(names).encode())
    return h.hexdigest()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small,parity")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    names = [n for n in args.configs.split(",") if n]
    os.makedirs(args.out, exist_ok=True)
    stamp_path = os.path.join(args.out, ".stamp")
    stamp = _stamp(names)
    if not args.force and os.path.exists(stamp_path) and open(stamp_path).read() == stamp:
        print("artifacts up to date (stamp match); skipping export")
        return

    for name in names:
        cfg = CONFIGS[name]
        print(f"exporting config '{name}' ({cfg.params():,} params, kernels={cfg.kernels})")
        ex = Exporter(args.out, cfg)
        extra = EXPORTS[name](ex)
        ex.write_manifest(extra or {})

    with open(stamp_path, "w") as fh:
        fh.write(stamp)
    print("done")


if __name__ == "__main__":
    main()
