"""Reference TP engine simulator (python twin of the Rust L3 engine).

Drives the *per-rank* L2 modules (model.py) with host-side AllReduces and
per-architecture residual scheduling — exactly the contract the Rust
coordinator implements. Tested against the monolithic archs.forward oracles;
serves as the executable specification for rust/src/engine/.

No Pallas/HLO here at test time if cfg.kernels == "ref"; with "pallas" the
same code paths exercise the interpret-mode kernels end to end.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import model
from .model import ModelConfig, ShardConfig


def shard_weights(cfg: ModelConfig, weights: dict, tp: int) -> list[dict]:
    """Slice the full pytree into per-rank shards (column/row split)."""
    ranks = []
    for t in range(tp):
        def cols(w):
            n = w.shape[1] // tp
            return w[:, t * n : (t + 1) * n]

        def rows(w):
            n = w.shape[0] // tp
            return w[t * n : (t + 1) * n, :]

        layers = []
        for lw in weights["layers"]:
            layers.append(
                dict(
                    attn_norm=lw["attn_norm"],
                    wq=cols(lw["wq"]), wk=cols(lw["wk"]), wv=cols(lw["wv"]),
                    wo=rows(lw["wo"]),
                    mlp_norm=lw["mlp_norm"],
                    wg=cols(lw["wg"]), wu=cols(lw["wu"]), wd=rows(lw["wd"]),
                )
            )
        ranks.append(
            dict(emb=weights["emb"], layers=layers,
                 final_norm=weights["final_norm"], lm=cols(weights["lm"]))
        )
    return ranks


class SimEngine:
    """Architecture-scheduled TP forward over per-rank modules + KV caches."""

    def __init__(self, cfg: ModelConfig, weights: dict, tp: int, arch: str, batch: int):
        self.cfg = cfg
        self.tp = tp
        self.arch = arch
        self.sc = cfg.shard(tp)
        self.ranks = shard_weights(cfg, weights, tp)
        self.batch = batch
        kvl, m, d = self.sc.kv_heads_l, cfg.max_seq, cfg.head_dim
        self.k_cache = [
            [jnp.zeros((batch, kvl, m, d), jnp.float32) for _ in range(cfg.layers)]
            for _ in range(tp)
        ]
        self.v_cache = [
            [jnp.zeros((batch, kvl, m, d), jnp.float32) for _ in range(cfg.layers)]
            for _ in range(tp)
        ]
        self.embed = model.make_embed(cfg)
        self.attn_prefill = model.make_attn_prefill(self.sc)
        self.attn_decode = model.make_attn_decode(self.sc)
        self.mlp = model.make_mlp(self.sc)
        self.fused_prefill = model.make_fused_prefill(self.sc)
        self.fused_decode = model.make_fused_decode(self.sc)
        self.lm_head = model.make_lm_head(self.sc)

    # -- module partials over all ranks --------------------------------------

    def _attn(self, xs: list, layer: int, phase: str, lens=None) -> list:
        outs = []
        for t in range(self.tp):
            lw = self.ranks[t]["layers"][layer]
            args = (xs[t], lw["attn_norm"], lw["wq"], lw["wk"], lw["wv"], lw["wo"],
                    self.k_cache[t][layer], self.v_cache[t][layer])
            if phase == "prefill":
                p, kc, vc = self.attn_prefill(*args)
            else:
                p, kc, vc = self.attn_decode(*args, lens)
            self.k_cache[t][layer] = kc
            self.v_cache[t][layer] = vc
            outs.append(p)
        return outs

    def _mlp(self, xs: list, layer: int) -> list:
        outs = []
        for t in range(self.tp):
            lw = self.ranks[t]["layers"][layer]
            outs.append(self.mlp(xs[t], lw["mlp_norm"], lw["wg"], lw["wu"], lw["wd"]))
        return outs

    def _fused(self, xs: list, layer: int, phase: str, lens=None) -> list:
        outs = []
        for t in range(self.tp):
            lw = self.ranks[t]["layers"][layer]
            # PaLM shared norm: attn_norm used for both branches
            args = (xs[t], lw["attn_norm"], lw["wq"], lw["wk"], lw["wv"], lw["wo"],
                    lw["wg"], lw["wu"], lw["wd"],
                    self.k_cache[t][layer], self.v_cache[t][layer])
            if phase == "prefill":
                p, kc, vc = self.fused_prefill(*args)
            else:
                p, kc, vc = self.fused_decode(*args, lens)
            self.k_cache[t][layer] = kc
            self.v_cache[t][layer] = vc
            outs.append(p)
        return outs

    @staticmethod
    def _allreduce(partials: list) -> jnp.ndarray:
        acc = partials[0]
        for p in partials[1:]:
            acc = acc + p
        return acc

    # -- one forward (prefill or decode), scheduled per architecture ---------

    def forward(self, tokens: jnp.ndarray, phase: str, lens=None) -> jnp.ndarray:
        """tokens: [B,S] (prefill) or [B,1] (decode). Returns logits [B,V]."""
        cfg = self.cfg
        x = self.embed(tokens, self.ranks[0]["emb"])
        arch = self.arch

        if arch in ("standard", "ladder", "hybrid", "upperbound"):
            ladder_from = {
                "standard": cfg.layers, "ladder": 0,
                "hybrid": cfg.layers // 2, "upperbound": cfg.layers,
            }[arch]
            pend_attn = pend_mlp = None
            for i in range(cfg.layers):
                if arch == "upperbound":
                    # comm deleted: rank-0 partial only (speed ceiling semantics)
                    x = x + self._attn([x] * self.tp, i, phase, lens)[0]
                    x = x + self._mlp([x] * self.tp, i)[0]
                    continue
                if i >= ladder_from:
                    if pend_attn is not None:
                        x = x + pend_attn
                    attn = self._allreduce(self._attn([x] * self.tp, i, phase, lens))
                    if pend_mlp is not None:
                        x = x + pend_mlp
                    mlp = self._allreduce(self._mlp([x] * self.tp, i))
                    pend_attn, pend_mlp = attn, mlp
                else:
                    x = x + self._allreduce(self._attn([x] * self.tp, i, phase, lens))
                    x = x + self._allreduce(self._mlp([x] * self.tp, i))
            if pend_attn is not None:
                x = x + pend_attn
            if pend_mlp is not None:
                x = x + pend_mlp
            xs_final = [x] * self.tp

        elif arch == "parallel":
            for i in range(cfg.layers):
                x = x + self._allreduce(self._fused([x] * self.tp, i, phase, lens))
            xs_final = [x] * self.tp

        elif arch in ("desync2", "desync4"):
            n = 2 if arch == "desync2" else 4
            rs = [x for _ in range(self.tp)]
            c = 0
            synced = True
            for i in range(cfg.layers):
                for kind in ("attn", "mlp"):
                    partials = (
                        self._attn(rs, i, phase, lens) if kind == "attn" else self._mlp(rs, i)
                    )
                    c += 1
                    if c % n == 0:
                        msg = [partials[t] + rs[t] / self.tp for t in range(self.tp)]
                        xs = self._allreduce(msg)
                        rs = [xs for _ in range(self.tp)]
                        synced = True
                    else:
                        rs = [rs[t] + partials[t] for t in range(self.tp)]
                        synced = False
            if not synced:
                xs = self._allreduce([r / self.tp for r in rs])
                rs = [xs for _ in range(self.tp)]
            xs_final = rs
        else:
            raise ValueError(arch)

        # lm head on the last position, vocab shards AllGathered
        last = xs_final[0].shape[1] - 1
        pieces = []
        for t in range(self.tp):
            xt = xs_final[t][:, last, :]
            pieces.append(self.lm_head(xt, self.ranks[t]["final_norm"], self.ranks[t]["lm"]))
        return jnp.concatenate(pieces, axis=-1)

    def prefill(self, tokens: jnp.ndarray) -> jnp.ndarray:
        return self.forward(tokens, "prefill")

    def decode(self, tokens: jnp.ndarray, lens: jnp.ndarray) -> jnp.ndarray:
        return self.forward(tokens, "decode", lens)
