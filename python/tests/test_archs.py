"""Architecture-semantics tests on the monolithic oracles (archs.py)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import archs
from compile.model import ModelConfig

CFG = ModelConfig(
    name="t", vocab=64, hidden=32, layers=4, heads=4, kv_heads=2,
    head_dim=8, ffn=64, max_seq=64, kernels="ref",
)
W = archs.init_weights(CFG, seed=3)
RNG = np.random.default_rng(7)
TOKENS = jnp.asarray(RNG.integers(0, CFG.vocab, (2, 12)), jnp.int32)


def logits(arch, tp=2, cfg=CFG, w=W, tokens=TOKENS):
    return np.asarray(archs.forward(cfg, w, tokens, arch, tp=tp))


def test_all_arches_run_and_are_finite():
    for arch in archs.ARCH_NAMES:
        out = logits(arch)
        assert out.shape == (2, 12, CFG.vocab)
        assert np.isfinite(out).all(), arch


@pytest.mark.parametrize("arch", ["standard", "ladder", "parallel", "hybrid"])
def test_synced_arches_are_tp_invariant(arch):
    """Exact-sum AllReduce => logits independent of TP degree (fp tolerance)."""
    np.testing.assert_allclose(logits(arch, tp=1), logits(arch, tp=2), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("arch", ["desync2", "desync4"])
def test_desync_depends_on_tp(arch):
    """Dropped AllReduces make the function TP-dependent (that's the point)."""
    a, b = logits(arch, tp=1), logits(arch, tp=2)
    assert np.abs(a - b).max() > 1e-3


def test_desync_tp1_equals_standard():
    """With one device every AllReduce is the identity: desync == standard."""
    np.testing.assert_allclose(logits("desync2", tp=1), logits("standard", tp=1), atol=1e-5)
    np.testing.assert_allclose(logits("desync4", tp=1), logits("standard", tp=1), atol=1e-5)


def test_ladder_differs_from_standard():
    """Stale inputs are a real architectural change, not a reparametrization."""
    assert np.abs(logits("ladder") - logits("standard")).max() > 1e-3


def test_hybrid_matches_standard_on_lower_half_only_model():
    """A 0-ladder-layer hybrid is exactly standard."""
    cfg0 = ModelConfig(**{**CFG.__dict__, "layers": 2})
    w0 = archs.init_weights(cfg0, seed=1)
    toks = TOKENS[:, :8]
    # hybrid converts layers >= layers//2 = 1, so differs from standard...
    hybrid = archs.forward(cfg0, w0, toks, "hybrid", tp=2)
    standard = archs.forward(cfg0, w0, toks, "standard", tp=2)
    assert np.abs(np.asarray(hybrid) - np.asarray(standard)).max() > 1e-4
    # ...but the internal helper with ladder_from == layers IS standard.
    same = archs._forward_synced(cfg0, w0, toks, 2, ladder_from=cfg0.layers)
    np.testing.assert_allclose(np.asarray(same), np.asarray(standard), atol=1e-6)


def test_upperbound_differs_from_everything():
    ub = logits("upperbound")
    assert np.abs(ub - logits("standard")).max() > 1e-3


def test_single_layer_ladder_still_shifts_mlp_input():
    """Even with one layer, ladder's MLP sees the residual WITHOUT the attn
    output (the in-layer stale routing of eq. 2) — so ladder != standard.

    But both attention modules see the same input x0, so zeroing the MLP
    weights makes the two architectures agree exactly.
    """
    cfg1 = ModelConfig(**{**CFG.__dict__, "layers": 1})
    w1 = archs.init_weights(cfg1, seed=2)
    toks = TOKENS[:, :6]
    a = archs.forward(cfg1, w1, toks, "ladder", tp=2)
    b = archs.forward(cfg1, w1, toks, "standard", tp=2)
    assert np.abs(np.asarray(a) - np.asarray(b)).max() > 1e-4
    # zero the MLP down-projection: h_mlp == 0, stale routing is invisible
    w1z = dict(w1, layers=[dict(w1["layers"][0], wd=jnp.zeros_like(w1["layers"][0]["wd"]))])
    az = archs.forward(cfg1, w1z, toks, "ladder", tp=2)
    bz = archs.forward(cfg1, w1z, toks, "standard", tp=2)
    np.testing.assert_allclose(np.asarray(az), np.asarray(bz), atol=1e-5)


def test_init_weights_deterministic():
    w2 = archs.init_weights(CFG, seed=3)
    np.testing.assert_array_equal(np.asarray(W["emb"]), np.asarray(w2["emb"]))
    np.testing.assert_array_equal(
        np.asarray(W["layers"][1]["wq"]), np.asarray(w2["layers"][1]["wq"])
    )


def test_param_count_matches_packing():
    from compile import train

    n_weights = sum(np.asarray(x).size for x in [W["emb"], W["final_norm"], W["lm"]])
    for lw in W["layers"]:
        n_weights += sum(np.asarray(x).size for x in lw.values())
    assert train.packed_size(CFG) == n_weights
    assert CFG.params() == n_weights


def test_desync_ablation_variant_differs():
    """desync2m (drop MLP's AR) is a different function from desync2
    (drop attention's AR, the paper's choice) at tp>1, and both collapse
    to standard at tp=1."""
    a = logits("desync2", tp=2)
    b = np.asarray(archs.forward(CFG, W, TOKENS, "desync2m", tp=2))
    assert np.abs(a - b).max() > 1e-4
    s1 = logits("standard", tp=1)
    m1 = np.asarray(archs.forward(CFG, W, TOKENS, "desync2m", tp=1))
    np.testing.assert_allclose(m1, s1, atol=1e-5)


def test_desync_retained_positions():
    """desync2 retains the MLP comm points (even counter), desync2m the
    attention ones — verified via comm-free equivalence: with tp=1 both are
    standard, with tp=2 zeroing the *retained* module's weights must make
    the dropped module's desync visible."""
    # zero all MLP down-projections: desync2 (drops attn AR) should still
    # differ from standard because attention partials stay local
    wz = dict(W, layers=[dict(lw, wd=jnp.zeros_like(lw["wd"])) for lw in W["layers"]])
    d2 = np.asarray(archs.forward(CFG, wz, TOKENS, "desync2", tp=2))
    st = np.asarray(archs.forward(CFG, wz, TOKENS, "standard", tp=2))
    assert np.abs(d2 - st).max() > 1e-4
    # while desync2m (drops MLP AR) with zeroed MLPs == standard: dropping
    # the AR of a zero module changes nothing (up to the joint-resync mean,
    # which is exact here since residuals stay identical across devices)
    d2m = np.asarray(archs.forward(CFG, wz, TOKENS, "desync2m", tp=2))
    np.testing.assert_allclose(d2m, st, atol=1e-4)
