"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes (and the GQA group structure / tile-divisibility
edge cases); assert_allclose against ref.py is the core correctness signal.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import get_kernels, ref

K = get_kernels("pallas")
RNG = np.random.default_rng(1234)


def randf(*shape):
    return jnp.asarray(RNG.standard_normal(shape, dtype=np.float32))


def assert_close(a, b, atol=2e-5, rtol=2e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=rtol)


# -- rmsnorm -----------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(1, 17),
    h=st.sampled_from([8, 32, 64, 96]),
    eps=st.sampled_from([1e-5, 1e-6]),
)
def test_rmsnorm_matches_ref(rows, h, eps):
    x = randf(rows, h)
    w = randf(h)
    assert_close(K.rmsnorm(x, w, eps), ref.rmsnorm(x, w, eps))


def test_rmsnorm_3d_shape():
    x = randf(2, 5, 32)
    w = randf(32)
    assert_close(K.rmsnorm(x, w), ref.rmsnorm(x, w))


def test_rmsnorm_unit_weight_is_pure_norm():
    x = randf(3, 16)
    w = jnp.ones(16)
    y = np.asarray(K.rmsnorm(x, w))
    rms = np.sqrt((y * y).mean(axis=-1))
    np.testing.assert_allclose(rms, np.ones(3), atol=1e-4)


# -- rope --------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    s=st.integers(1, 20),
    d=st.sampled_from([8, 16, 32]),
)
def test_rope_matches_ref(b, h, s, d):
    x = randf(b, h, s, d)
    pos = jnp.arange(s, dtype=jnp.int32)
    assert_close(K.rope(x, pos), ref.rope(x, pos))


def test_rope_per_row_positions():
    x = randf(3, 2, 1, 16)
    pos = jnp.asarray([[4], [0], [97]], dtype=jnp.int32)
    assert_close(K.rope(x, pos), ref.rope(x, pos))


def test_rope_position_zero_is_identity():
    x = randf(1, 2, 1, 16)
    pos = jnp.zeros((1,), jnp.int32)
    assert_close(K.rope(x, pos), x)


def test_rope_preserves_norm():
    # rotation is orthogonal on each (d, d+half) pair
    x = randf(2, 2, 6, 32)
    pos = jnp.arange(6, dtype=jnp.int32)
    y = K.rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )


# -- flash attention ----------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 2),
    kv_heads=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([4, 16, 24, 32]),
    d=st.sampled_from([8, 16]),
)
def test_flash_attention_matches_ref(b, kv_heads, group, s, d):
    hq = kv_heads * group
    q = randf(b, hq, s, d)
    k = randf(b, kv_heads, s, d)
    v = randf(b, kv_heads, s, d)
    assert_close(K.attention(q, k, v, causal=True), ref.attention(q, k, v, causal=True), atol=1e-4, rtol=1e-4)


def test_flash_attention_noncausal():
    q, k, v = randf(1, 2, 16, 8), randf(1, 2, 16, 8), randf(1, 2, 16, 8)
    assert_close(K.attention(q, k, v, causal=False), ref.attention(q, k, v, causal=False), atol=1e-4)


def test_flash_attention_first_token_is_v0():
    # causal: position 0 attends only to itself
    q, k, v = randf(1, 1, 8, 8), randf(1, 1, 8, 8), randf(1, 1, 8, 8)
    out = np.asarray(K.attention(q, k, v, causal=True))
    np.testing.assert_allclose(out[0, 0, 0], np.asarray(v)[0, 0, 0], atol=1e-5)


def test_flash_attention_odd_seq_tiles():
    # s not divisible by the default tile: exercises the tile-shrink path
    q, k, v = randf(1, 2, 18, 8), randf(1, 2, 18, 8), randf(1, 2, 18, 8)
    assert_close(K.attention(q, k, v), ref.attention(q, k, v), atol=1e-4)


# -- decode attention ----------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 3),
    kv_heads=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2]),
    m=st.sampled_from([16, 32, 64]),
    d=st.sampled_from([8, 16]),
    data=st.data(),
)
def test_decode_attention_matches_ref(b, kv_heads, group, m, d, data):
    hq = kv_heads * group
    q = randf(b, hq, 1, d)
    kc = randf(b, kv_heads, m, d)
    vc = randf(b, kv_heads, m, d)
    lens = jnp.asarray(
        data.draw(st.lists(st.integers(1, m), min_size=b, max_size=b)), jnp.int32
    )
    assert_close(K.decode_attention(q, kc, vc, lens), ref.decode_attention(q, kc, vc, lens), atol=1e-4)


def test_decode_attention_scalar_length():
    q, kc, vc = randf(2, 2, 1, 8), randf(2, 1, 32, 8), randf(2, 1, 32, 8)
    assert_close(K.decode_attention(q, kc, vc, 7), ref.decode_attention(q, kc, vc, 7), atol=1e-4)


def test_decode_attention_length_one_returns_v0():
    q, kc, vc = randf(1, 1, 1, 8), randf(1, 1, 16, 8), randf(1, 1, 16, 8)
    out = np.asarray(K.decode_attention(q, kc, vc, 1))
    np.testing.assert_allclose(out[0, 0, 0], np.asarray(vc)[0, 0, 0], atol=1e-5)


def test_decode_attention_ignores_garbage_beyond_length():
    q = randf(1, 1, 1, 8)
    kc, vc = randf(1, 1, 16, 8), randf(1, 1, 16, 8)
    out1 = K.decode_attention(q, kc, vc, 5)
    kc2 = kc.at[:, :, 5:].set(1e6)  # poison masked slots
    vc2 = vc.at[:, :, 5:].set(-1e6)
    out2 = K.decode_attention(q, kc2, vc2, 5)
    assert_close(out1, out2)


# -- swiglu / matmul -----------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(rows=st.integers(1, 20), f=st.sampled_from([8, 48, 96]))
def test_swiglu_matches_ref(rows, f):
    g, u = randf(rows, f), randf(rows, f)
    assert_close(K.swiglu(g, u), ref.swiglu(g, u))


def test_swiglu_zero_gate_is_zero():
    g = jnp.zeros((4, 16))
    u = randf(4, 16)
    np.testing.assert_allclose(np.asarray(K.swiglu(g, u)), 0.0)


@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([1, 7, 32, 48]),
    k=st.sampled_from([8, 33, 64]),
    n=st.sampled_from([8, 24, 64]),
)
def test_matmul_matches_ref(m, k, n):
    a, b = randf(m, k), randf(k, n)
    assert_close(K.matmul(a, b), ref.matmul(a, b), atol=1e-4, rtol=1e-4)


def test_matmul_identity():
    a = randf(8, 8)
    eye = jnp.eye(8)
    assert_close(K.matmul(a, eye), a, atol=1e-6)
