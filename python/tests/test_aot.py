"""AOT exporter tests: HLO text well-formedness + manifest shape integrity."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.model import ModelConfig

CFG = ModelConfig(
    name="unit", vocab=64, hidden=32, layers=2, heads=4, kv_heads=2,
    head_dim=8, ffn=64, max_seq=32, kernels="ref",
)


def test_to_hlo_text_roundtrips_simple_fn():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert text.startswith("HloModule")
    assert "f32[2,2]" in text


def test_exporter_writes_module_and_manifest(tmp_path):
    ex = aot.Exporter(str(tmp_path), CFG)
    sc = CFG.shard(2)
    ex.export(
        "mlp__tp2__b1__s4", model.make_mlp(sc),
        [aot.f32(1, 4, 32), aot.f32(32), aot.f32(32, 32), aot.f32(32, 32), aot.f32(32, 32)],
        ["x", "norm_w", "w_gate", "w_up", "w_down"],
    )
    ex.write_manifest({"tps": [2]})
    mdir = tmp_path / "unit"
    text = (mdir / "mlp__tp2__b1__s4.hlo.txt").read_text()
    assert text.startswith("HloModule")
    man = json.loads((mdir / "manifest.json").read_text())
    assert man["config"]["hidden"] == 32
    mod = man["modules"]["mlp__tp2__b1__s4"]
    assert mod["inputs"][0]["shape"] == [1, 4, 32]
    assert mod["outputs"][0]["shape"] == [1, 4, 32]
    # packing covers every parameter exactly once
    assert man["packing"]["total"] == CFG.params()
    offs = man["packing"]["tensors"]
    total = 0
    for t in offs:
        assert t["offset"] == total
        total += int(np.prod(t["shape"]))
    assert total == man["packing"]["total"]


def test_stamp_changes_with_config_list():
    assert aot._stamp(["tiny"]) != aot._stamp(["tiny", "small"])


def test_export_attn_decode_hlo_contains_parameters(tmp_path):
    ex = aot.Exporter(str(tmp_path), CFG)
    sc = CFG.shard(2)
    cache = aot.f32(1, sc.kv_heads_l, CFG.max_seq, CFG.head_dim)
    ex.export(
        "attn_decode__tp2__b1", model.make_attn_decode(sc),
        [aot.f32(1, 1, 32), aot.f32(32), aot.f32(32, sc.q_dim_l), aot.f32(32, sc.kv_dim_l),
         aot.f32(32, sc.kv_dim_l), aot.f32(sc.q_dim_l, 32), cache, cache, aot.i32(1)],
        ["x", "norm_w", "wq", "wk", "wv", "wo", "k_cache", "v_cache", "lens"],
    )
    text = (tmp_path / "unit" / "attn_decode__tp2__b1.hlo.txt").read_text()
    # 9 parameters expected in the entry computation
    assert text.count("parameter(") >= 9
