"""The executable L3 specification: per-rank modules + host AllReduce +
per-architecture scheduling must reproduce the monolithic oracles, for
prefill AND for incremental KV-cache decode.

This is the contract the Rust engine implements; any scheduling or cache
bug shows up here first.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import archs
from compile.engine_sim import SimEngine
from compile.model import ModelConfig

CFG = ModelConfig(
    name="t", vocab=64, hidden=32, layers=4, heads=4, kv_heads=2,
    head_dim=8, ffn=64, max_seq=64, kernels="ref",
)
W = archs.init_weights(CFG, seed=5)
RNG = np.random.default_rng(11)

PROMPT = 8
STEPS = 4
B = 2
SEQ = jnp.asarray(RNG.integers(0, CFG.vocab, (B, PROMPT + STEPS)), jnp.int32)


def oracle_logits(arch, upto, tp=2):
    """Monolithic forward over SEQ[:, :upto]; last-position logits."""
    out = archs.forward(CFG, W, SEQ[:, :upto], arch, tp=tp)
    return np.asarray(out[:, -1, :])


@pytest.mark.parametrize("arch", ["standard", "ladder", "parallel", "hybrid", "desync2", "desync4"])
def test_engine_prefill_then_decode_matches_oracle(arch):
    eng = SimEngine(CFG, W, tp=2, arch=arch, batch=B)
    # prefill the prompt
    got = np.asarray(eng.prefill(SEQ[:, :PROMPT]))
    np.testing.assert_allclose(got, oracle_logits(arch, PROMPT), atol=2e-4, rtol=2e-4)
    # teacher-forced incremental decode: each step must equal a full forward
    for t in range(STEPS):
        lens = jnp.full((B,), PROMPT + t, jnp.int32)
        tok = SEQ[:, PROMPT + t : PROMPT + t + 1]
        got = np.asarray(eng.decode(tok, lens))
        want = oracle_logits(arch, PROMPT + t + 1)
        np.testing.assert_allclose(got, want, atol=5e-4, rtol=5e-4)


def test_engine_tp1_equals_tp2_for_standard():
    e1 = SimEngine(CFG, W, tp=1, arch="standard", batch=B)
    e2 = SimEngine(CFG, W, tp=2, arch="standard", batch=B)
    a = np.asarray(e1.prefill(SEQ[:, :PROMPT]))
    b = np.asarray(e2.prefill(SEQ[:, :PROMPT]))
    np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


def test_engine_upperbound_runs_but_diverges():
    eng = SimEngine(CFG, W, tp=2, arch="upperbound", batch=B)
    got = np.asarray(eng.prefill(SEQ[:, :PROMPT]))
    assert np.isfinite(got).all()
    ref = oracle_logits("standard", PROMPT)
    assert np.abs(got - ref).max() > 1e-3  # comm deletion is wrong numerics


def test_engine_ragged_batch_decode():
    """Continuous-batching shape: rows at different lengths decode correctly."""
    arch = "standard"
    eng = SimEngine(CFG, W, tp=2, arch=arch, batch=2)
    eng.prefill(SEQ[:, :PROMPT])
    # advance row 0 by one token; row 1 stays (its slot decodes a dummy token
    # that we simply ignore — its cache row will be overwritten next step)
    lens = jnp.asarray([PROMPT, PROMPT], jnp.int32)
    eng.decode(SEQ[:, PROMPT : PROMPT + 1], lens)
    # now rows are at different true lengths; re-decode row 1's real token
    lens2 = jnp.asarray([PROMPT + 1, PROMPT + 1], jnp.int32)
    got = np.asarray(eng.decode(SEQ[:, PROMPT + 1 : PROMPT + 2], lens2))
    want = oracle_logits(arch, PROMPT + 2)
    np.testing.assert_allclose(got, want, atol=5e-4, rtol=5e-4)


def test_engine_pallas_kernels_smoke():
    """Same engine path with the Pallas kernels (tiny shapes, one arch)."""
    cfg = ModelConfig(
        name="t", vocab=64, hidden=32, layers=2, heads=4, kv_heads=2,
        head_dim=8, ffn=64, max_seq=32, kernels="pallas",
    )
    w = archs.init_weights(cfg, seed=5)
    seq = SEQ[:, :6]
    eng = SimEngine(cfg, w, tp=2, arch="ladder", batch=B)
    got = np.asarray(eng.prefill(seq))
    want = np.asarray(archs.forward(cfg, w, seq, "ladder", tp=2)[:, -1, :])
    np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-3)
