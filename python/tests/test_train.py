"""Training-graph tests: packing roundtrip, loss behaviour, AdamW step."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import archs, train
from compile.model import ModelConfig

CFG = ModelConfig(
    name="t", vocab=64, hidden=32, layers=4, heads=4, kv_heads=2,
    head_dim=8, ffn=64, max_seq=64, kernels="ref",
)
RNG = np.random.default_rng(21)


def tokens(b=4, s=16):
    return jnp.asarray(RNG.integers(0, CFG.vocab, (b, s)), jnp.int32)


def test_pack_unpack_roundtrip():
    w = archs.init_weights(CFG, seed=0)
    vec = train.pack(CFG, w)
    assert vec.shape == (train.packed_size(CFG),)
    w2 = train.unpack(CFG, vec)
    np.testing.assert_array_equal(np.asarray(w["emb"]), np.asarray(w2["emb"]))
    np.testing.assert_array_equal(np.asarray(w["lm"]), np.asarray(w2["lm"]))
    for lw, lw2 in zip(w["layers"], w2["layers"]):
        for k in lw:
            np.testing.assert_array_equal(np.asarray(lw[k]), np.asarray(lw2[k]))


def test_initial_loss_near_uniform():
    """Fresh init should score ~log(V) per token."""
    w = train.pack(CFG, archs.init_weights(CFG, seed=0))
    for arch in ("standard", "ladder", "parallel", "desync2"):
        loss = float(train.loss_fn(CFG, arch, w, tokens()))
        assert abs(loss - np.log(CFG.vocab)) < 1.0, (arch, loss)


@pytest.mark.parametrize("arch", ["standard", "ladder", "desync4"])
def test_train_step_reduces_loss(arch):
    step_fn = train.make_train_step(CFG, arch)
    w = train.pack(CFG, archs.init_weights(CFG, seed=0))
    m = jnp.zeros_like(w)
    v = jnp.zeros_like(w)
    toks = tokens()
    losses = []
    step = jnp.asarray(0, jnp.int32)
    for _ in range(8):
        loss, w, m, v = step_fn(w, m, v, step, jnp.asarray(1e-3, jnp.float32), toks)
        step = step + 1
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.05, losses


def test_eval_metrics_consistent_with_loss():
    w = train.pack(CFG, archs.init_weights(CFG, seed=0))
    toks = tokens(b=2, s=10)
    fn = train.make_eval_metrics(CFG, "standard")
    loss_sum, hits = fn(w, toks)
    n_pred = 2 * 9
    mean = float(loss_sum) / n_pred
    direct = float(train.loss_fn(CFG, "standard", w, toks))
    assert abs(mean - direct) < 1e-4
    assert 0 <= int(hits) <= n_pred


def test_train_step_changes_all_tensor_groups():
    """AdamW with weight decay must touch every packed tensor."""
    step_fn = train.make_train_step(CFG, "standard")
    w = train.pack(CFG, archs.init_weights(CFG, seed=0))
    m = jnp.zeros_like(w)
    v = jnp.zeros_like(w)
    _, w2, _, _ = step_fn(w, m, v, jnp.asarray(0, jnp.int32), jnp.asarray(1e-3, jnp.float32), tokens())
    delta = np.asarray(w2 - w)
    off = 0
    for entry, shape in train.packing_table(CFG):
        n = int(np.prod(shape))
        assert np.abs(delta[off : off + n]).max() > 0, entry
        off += n
