//! `cargo bench --bench fig2_throughput` — regenerates the paper's Figure 2 throughput grid
//! from the performance model (see DESIGN.md experiment index).

use ladder_infer::perfmodel::tables;
use ladder_infer::util::bench::time_it;

fn main() {
    for t in tables::fig2() { t.print(); }
    time_it("regen", 1, 3, || { let _ = tables::fig2(); });
}
