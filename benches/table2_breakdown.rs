//! `cargo bench --bench table2_breakdown` — regenerates the paper's Table 2 70B breakdown
//! from the performance model (see DESIGN.md experiment index).

use ladder_infer::perfmodel::tables;
use ladder_infer::util::bench::time_it;

fn main() {
    tables::table2().print();
    time_it("regen", 1, 3, || { let _ = tables::table2(); });
}
