//! `cargo bench --bench table1_speedup` — regenerates the paper's Table 1 size sweep
//! from the performance model (see DESIGN.md experiment index).

use ladder_infer::perfmodel::tables;
use ladder_infer::util::bench::time_it;

fn main() {
    tables::table1().print();
    time_it("regen", 1, 3, || { let _ = tables::table1(); });
}
