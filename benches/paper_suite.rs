//! `cargo bench --bench paper_suite` — regenerates every paper table/figure
//! via the performance model and times the generators (criterion is
//! unavailable offline; this is a `harness = false` custom bench).
//!
//! Individual tables: `cargo bench --bench paper_suite -- table1 fig4 ...`

use ladder_infer::perfmodel::tables;
use ladder_infer::util::bench::time_it;

fn main() {
    let filter: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let want = |n: &str| filter.is_empty() || filter.iter().any(|f| f == n);

    println!("paper reproduction suite (perfmodel)\n");
    if want("table1") {
        let t = tables::table1();
        t.print();
        time_it("regen: table1 (size sweep)", 1, 3, || {
            let _ = tables::table1();
        });
    }
    if want("table2") {
        let t = tables::table2();
        t.print();
        time_it("regen: table2 (70B breakdown)", 1, 3, || {
            let _ = tables::table2();
        });
    }
    if want("fig2") {
        for t in tables::fig2() {
            t.print();
        }
        time_it("regen: fig2 (throughput grid)", 1, 3, || {
            let _ = tables::fig2();
        });
    }
    if want("fig3") {
        tables::fig3().print();
        time_it("regen: fig3 (405B cross-node)", 1, 3, || {
            let _ = tables::fig3();
        });
    }
    if want("fig4") {
        tables::fig4().print();
        time_it("regen: fig4 (pareto sweep)", 1, 3, || {
            let _ = tables::fig4();
        });
    }
    if want("table6") {
        tables::table6().print();
        time_it("regen: table6 (desync breakdown)", 1, 3, || {
            let _ = tables::table6();
        });
    }
    if want("codec") {
        tables::codec_compound().print();
        time_it("regen: codec (quantized-collective compounding)", 1, 3, || {
            let _ = tables::codec_compound();
        });
    }
}
