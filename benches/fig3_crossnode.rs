//! `cargo bench --bench fig3_crossnode` — regenerates the paper's Figure 3 405B cross-node
//! from the performance model (see DESIGN.md experiment index).

use ladder_infer::perfmodel::tables;
use ladder_infer::util::bench::time_it;

fn main() {
    tables::fig3().print();
    time_it("regen", 1, 3, || { let _ = tables::fig3(); });
}
