//! `cargo bench --bench fig3_crossnode` — the paper's Figure 3 (405B
//! cross-node TP16) from the performance model, plus a *measured* sweep on
//! the real tiny engine: architecture x topology x split-batch overlap over
//! the ms-scale fabrics, so the ladder-vs-TokenWeave-style head-to-head is
//! a wall-clock fact and not just a model output. Dumps the
//! machine-readable sweep to `BENCH_fig3_overlap.json` (CI uploads it; the
//! hard gates live in `tests/overlap_wallclock.rs`).
//!
//! The headline derived numbers, per topology:
//!   gap_recovered = (std_none - std_split4) / (std_none - ladder_none)
//! — the fraction of the standard-vs-ladder wall-clock gap that split-batch
//! overlap recovers *without* changing the architecture. Ladder+none should
//! still hold the frontier.

use std::rc::Rc;

use ladder_infer::comm::{Codec, Interconnect};
use ladder_infer::engine::{generate, KvLayout, OverlapMode, RuntimeKind, Sampler, TpEngine};
use ladder_infer::model::{Arch, WeightStore};
use ladder_infer::perfmodel::tables;
use ladder_infer::runtime::Exec;
use ladder_infer::util::bench::{time_it, Table};
use ladder_infer::util::json::Json;

const PROMPT: usize = 16;
const TP: usize = 2;
const BATCH: usize = 4;

struct Measured {
    prefill: f64,
    decode: f64,
    modeled: f64,
    exposed: f64,
    bytes_intra: usize,
    bytes_cross: usize,
}

fn run(
    exec: &Rc<Exec>,
    weights: &WeightStore,
    arch: Arch,
    fabric: Interconnect,
    overlap: OverlapMode,
    steps: usize,
) -> anyhow::Result<Measured> {
    let mut engine = TpEngine::with_overlap(
        exec.clone(),
        weights,
        TP,
        arch,
        BATCH,
        fabric,
        RuntimeKind::default(),
        KvLayout::Slab,
        Codec::default(),
        overlap,
    )?;
    let prompts: Vec<Vec<i32>> = (0..BATCH).map(|b| vec![b as i32 + 1; PROMPT]).collect();
    let report = generate::generate(&mut engine, &prompts, steps, &Sampler::Greedy)?;
    Ok(Measured {
        prefill: report.prefill_time.as_secs_f64(),
        decode: report.decode_time.as_secs_f64(),
        modeled: report.comm.modeled_total.as_secs_f64(),
        exposed: report.comm.exposed_total.as_secs_f64(),
        bytes_intra: report.comm.bytes_intra,
        bytes_cross: report.comm.bytes_cross,
    })
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");

    // the modeled figures stay: paper Figure 3 + the overlap compounding table
    tables::fig3().print();
    tables::overlap_compound().print();
    time_it("regen fig3 (modeled)", 1, 3, || {
        let _ = tables::fig3();
    });

    // -- measured sweep: arch x topology x overlap on the real tiny engine --
    let exec = Rc::new(Exec::native_named("tiny")?);
    let weights = WeightStore::random(exec.cfg(), 42);
    let steps = if smoke { 4 } else { 8 };
    let arches: &[Arch] = if smoke {
        &[Arch::Standard, Arch::Ladder]
    } else {
        &[Arch::Standard, Arch::Parallel, Arch::Ladder, Arch::Upperbound]
    };
    let topologies = [
        Interconnect::parse("slow")?,
        // hierarchical two-tier testbed: every rank its own node, all
        // AllReduce traffic on the slow cross tier
        Interconnect::parse("two_tier:local:slow:1")?,
    ];
    let overlaps = [OverlapMode::None, OverlapMode::Split2, OverlapMode::Split4];

    let mut table = Table::new(
        &format!(
            "fig3 measured sweep: tiny tp{TP} bs{BATCH}, prompt {PROMPT}, {steps} decode steps"
        ),
        &["topology", "arch", "overlap", "prefill ms", "decode ms", "hidden %", "intra/cross KB"],
    );
    let mut rows: Vec<Json> = Vec::new();
    // (topology name, arch, overlap) -> total seconds, for the gap math
    let mut totals: Vec<(String, Arch, OverlapMode, f64)> = Vec::new();
    for fabric in topologies {
        for &arch in arches {
            for overlap in overlaps {
                let m = run(&exec, &weights, arch, fabric, overlap, steps)?;
                let total = m.prefill + m.decode;
                let hidden = if m.modeled > 0.0 { 1.0 - m.exposed / m.modeled } else { 1.0 };
                table.row(&[
                    fabric.name(),
                    arch.name(),
                    overlap.name().to_string(),
                    format!("{:.1}", m.prefill * 1e3),
                    format!("{:.1}", m.decode * 1e3),
                    format!("{:.0}", hidden * 100.0),
                    format!("{}/{}", m.bytes_intra >> 10, m.bytes_cross >> 10),
                ]);
                rows.push(
                    Json::obj()
                        .set("topology", fabric.name())
                        .set("arch", arch.name())
                        .set("overlap", overlap.name())
                        .set("prefill_s", m.prefill)
                        .set("decode_s", m.decode)
                        .set("total_s", total)
                        .set("comm_modeled_s", m.modeled)
                        .set("comm_exposed_s", m.exposed)
                        .set("bytes_intra", m.bytes_intra)
                        .set("bytes_cross", m.bytes_cross),
                );
                totals.push((fabric.name(), arch, overlap, total));
            }
        }
    }
    table.print();

    // headline: how much of the standard-vs-ladder gap split4 recovers
    let mut recovery = Vec::new();
    for fabric in topologies {
        let total = |arch: Arch, ov: OverlapMode| {
            totals
                .iter()
                .find(|(t, a, o, _)| *t == fabric.name() && *a == arch && *o == ov)
                .map(|(_, _, _, s)| *s)
        };
        let (Some(std_none), Some(std_s4), Some(lad_none)) = (
            total(Arch::Standard, OverlapMode::None),
            total(Arch::Standard, OverlapMode::Split4),
            total(Arch::Ladder, OverlapMode::None),
        ) else {
            continue;
        };
        let gap = std_none - lad_none;
        let recovered = if gap > 0.0 { (std_none - std_s4) / gap } else { 0.0 };
        println!(
            "{}: standard+split4 recovers {:.0}% of the standard-vs-ladder gap \
             (ladder+none leads: {})",
            fabric.name(),
            recovered * 100.0,
            lad_none < std_s4,
        );
        recovery.push(
            Json::obj()
                .set("topology", fabric.name())
                .set("std_none_s", std_none)
                .set("std_split4_s", std_s4)
                .set("ladder_none_s", lad_none)
                .set("gap_recovered", recovered)
                .set("ladder_none_leads", lad_none < std_s4),
        );
    }

    let report = Json::obj()
        .set("bench", "fig3_overlap")
        .set("model", "tiny")
        .set("smoke", smoke)
        .set("tp", TP)
        .set("batch", BATCH)
        .set("prompt", PROMPT)
        .set("decode_steps", steps)
        .set("runtime", RuntimeKind::default().name())
        .set("rows", Json::Arr(rows))
        .set("gap_recovery", Json::Arr(recovery));
    // anchor at the workspace root: cargo runs bench binaries with cwd =
    // the package root (rust/), which is not where CI's upload glob looks
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_fig3_overlap.json");
    std::fs::write(&out, report.to_pretty())?;
    println!("\nwrote {}", out.display());
    Ok(())
}
