//! `cargo bench --bench fig4_pareto` — regenerates the paper's Figure 4 pareto frontier
//! from the performance model (see DESIGN.md experiment index).

use ladder_infer::perfmodel::tables;
use ladder_infer::util::bench::time_it;

fn main() {
    tables::fig4().print();
    println!("pareto counts: {:?}", tables::fig4_pareto_counts());
    time_it("regen", 1, 3, || { let _ = tables::fig4(); });
}
