//! Real-engine microbenchmarks (`cargo bench --bench engine_hotpath`):
//! decode-step latency per architecture x rank runtime on the tiny model,
//! collective throughput, and the host-side overhead split — the measured
//! counterpart of the perfmodel numbers and the input to the §Perf
//! optimization log. Dumps the machine-readable report to
//! `BENCH_engine_hotpath.json` (the committed `BENCH_pr1.json` is the PR 1
//! reference capture from an 8-core dev host).
//!
//! Runs on the default native backend with no artifacts. `--smoke` switches
//! to a reduced-iteration mode for CI: same coverage, minimal wall time.

use std::collections::VecDeque;
use std::rc::Rc;

use ladder_infer::comm::{CollectiveEngine, Fabric, Interconnect};
use ladder_infer::engine::{RuntimeKind, TpEngine};
use ladder_infer::model::{Arch, HostTensor, WeightStore};
use ladder_infer::runtime::Exec;
use ladder_infer::util::bench::{time_it, Table};
use ladder_infer::util::json::Json;

const ARCHES: [Arch; 6] = [
    Arch::Standard,
    Arch::Parallel,
    Arch::Ladder,
    Arch::Desync(2),
    Arch::Desync(4),
    Arch::Upperbound,
];

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let exec = Rc::new(Exec::native_named("tiny")?);
    let weights = match exec.artifacts_opt() {
        Some(art) => WeightStore::from_flat(
            &art.read_f32("testvec_weights.f32")?,
            art.packing()?,
            exec.cfg().layers,
        )?,
        None => WeightStore::random(exec.cfg(), 42),
    };
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    // -- collective microbench ------------------------------------------------
    // §Perf: the message pool is cloned *outside* the timed closure — the old
    // bench cloned inside it, so the "collective" number was dominated by
    // host memcpy. The memcpy is timed separately below to keep it visible.
    println!("== collective engine ==");
    let (warmup, iters) = if smoke { (1, 3) } else { (3, 20) };
    for tp in [2usize, 4, 8] {
        let ce = CollectiveEngine::new(tp, Interconnect::new(Fabric::Local));
        let parts: Vec<HostTensor> = (0..tp)
            .map(|_| HostTensor::new(vec![4, 64, 256], vec![1.0; 4 * 64 * 256]))
            .collect();
        let mut pool: VecDeque<Vec<HostTensor>> =
            (0..warmup + iters).map(|_| parts.clone()).collect();
        time_it(&format!("allreduce 256KiB x tp{tp}"), warmup, iters, || {
            let p = pool.pop_front().expect("pool sized to warmup+iters");
            let _ = ce.allreduce(p).unwrap().wait();
        });
        time_it(&format!("  (clone 256KiB x tp{tp} memcpy)"), warmup, iters, || {
            std::hint::black_box(parts.clone());
        });
    }

    // -- decode-step latency per architecture x runtime -----------------------
    let backend = exec.backend_name();
    println!("\n== decode step (tiny model, tp=2, {backend} modules, {cores} cores) ==");
    let (dwarm, diters) = if smoke { (1, 5) } else { (3, 15) };
    let mut table = Table::new(
        "decode-step latency (sequential vs threaded runtime)",
        &["arch", "seq mean ms", "thr mean ms", "thr speedup"],
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut speedups: Vec<(String, f64)> = Vec::new();
    for arch in ARCHES {
        let mut means = [0.0f64; 2];
        for (ri, runtime) in [RuntimeKind::Sequential, RuntimeKind::Threaded].iter().enumerate() {
            let mut engine = TpEngine::with_runtime(
                exec.clone(),
                &weights,
                2,
                arch,
                2,
                Interconnect::new(Fabric::Pcie),
                *runtime,
            )?;
            // prime: prefill 16 tokens
            let tokens = vec![1i32; 2 * 16];
            engine.prefill(&tokens, 16, &[16, 16])?;
            let s = time_it(
                &format!("decode step [{} / {}]", arch.name(), runtime.name()),
                dwarm,
                diters,
                || {
                    let _ = engine.decode(&[1, 2]).unwrap();
                },
            );
            means[ri] = s.mean();
            rows.push(
                Json::obj()
                    .set("arch", arch.name())
                    .set("runtime", runtime.name())
                    .set("mean_ms", s.mean() * 1e3)
                    .set("p50_ms", s.p50() * 1e3),
            );
        }
        let speedup = means[0] / means[1];
        speedups.push((arch.name(), speedup));
        table.row(&[
            arch.name(),
            format!("{:.2}", means[0] * 1e3),
            format!("{:.2}", means[1] * 1e3),
            format!("{speedup:.2}x"),
        ]);
    }
    table.print();

    let report = Json::obj()
        .set("bench", "engine_hotpath")
        .set("model", "tiny")
        .set("backend", backend)
        .set("smoke", smoke)
        .set("tp", 2usize)
        .set("batch", 2usize)
        .set("fabric", "pcie")
        .set("host_cores", cores)
        .set("decode_rows", Json::Arr(rows))
        .set(
            "threaded_speedup",
            Json::Obj(speedups.into_iter().map(|(a, s)| (a, Json::Num(s))).collect()),
        );
    // anchor at the workspace root: cargo runs bench binaries with cwd =
    // the package root (rust/), which is not where CI's upload glob looks
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_engine_hotpath.json");
    std::fs::write(&out, report.to_pretty())?;
    println!("\nwrote {}", out.display());
    Ok(())
}
