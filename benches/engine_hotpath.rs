//! Real-engine microbenchmarks (`cargo bench --bench engine_hotpath`):
//! decode-step latency per architecture on the tiny model, collective
//! throughput, and the host-side overhead split — the measured counterpart
//! of the perfmodel numbers and the input to the §Perf optimization log.

use std::rc::Rc;

use ladder_infer::comm::{CollectiveEngine, Fabric, Interconnect};
use ladder_infer::engine::TpEngine;
use ladder_infer::model::{Arch, HostTensor, WeightStore};
use ladder_infer::runtime::ExecCache;
use ladder_infer::util::bench::{time_it, Table};

fn main() -> anyhow::Result<()> {
    let exec = Rc::new(ExecCache::open("tiny")?);
    let cfg = exec.artifacts().config.clone();
    let flat = exec.artifacts().read_f32("testvec_weights.f32")?;
    let weights = WeightStore::from_flat(&flat, exec.artifacts().packing()?, cfg.layers)?;

    // -- collective microbench ------------------------------------------------
    println!("== collective engine ==");
    for tp in [2usize, 4, 8] {
        let ce = CollectiveEngine::new(tp, Interconnect::new(Fabric::Local));
        let parts: Vec<HostTensor> = (0..tp)
            .map(|_| HostTensor::new(vec![4, 64, 256], vec![1.0; 4 * 64 * 256]))
            .collect();
        time_it(&format!("allreduce 256KiB x tp{tp}"), 3, 20, || {
            let p = parts.clone();
            let _ = ce.allreduce(p).unwrap().wait();
        });
    }

    // -- decode-step latency per architecture ---------------------------------
    println!("\n== decode step (tiny model, tp=2, real modules) ==");
    let mut table = Table::new("decode-step latency", &["arch", "mean ms", "p50 ms"]);
    for arch in [
        Arch::Standard,
        Arch::Parallel,
        Arch::Ladder,
        Arch::Desync(2),
        Arch::Desync(4),
        Arch::Upperbound,
    ] {
        let mut engine = TpEngine::new(
            exec.clone(),
            &weights,
            2,
            arch,
            2,
            Interconnect::new(Fabric::Pcie),
        )?;
        // prime: prefill 16 tokens
        let tokens = vec![1i32; 2 * 16];
        engine.prefill(&tokens, 16, &[16, 16])?;
        let s = time_it(&format!("decode step [{}]", arch.name()), 3, 15, || {
            let _ = engine.decode(&[1, 2]).unwrap();
        });
        table.row(&[
            arch.name(),
            format!("{:.2}", s.mean() * 1e3),
            format!("{:.2}", s.p50() * 1e3),
        ]);
    }
    table.print();
    Ok(())
}
