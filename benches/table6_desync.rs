//! `cargo bench --bench table6_desync` — regenerates the paper's Table 6 desync breakdown
//! from the performance model (see DESIGN.md experiment index).

use ladder_infer::perfmodel::tables;
use ladder_infer::util::bench::time_it;

fn main() {
    tables::table6().print();
    time_it("regen", 1, 3, || { let _ = tables::table6(); });
}
